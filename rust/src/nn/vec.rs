//! Host-vectorized (`VecLanes`) kernel backend.
//!
//! Every "SIMD" forward in this engine models the paper's Cortex-M
//! `__SMLAD` kernels in the **micro-op event stream** while the host
//! executes plain scalar Rust. This module adds a second *host execution*
//! backend for the hot inner loops — blocked im2col matmul, depthwise,
//! shift and dense — written as fixed-width i16 lane blocks that LLVM's
//! autovectorizer reliably lowers to real SIMD (`pmaddwd`-class on
//! x86-64 SSE2, `smlal`-class on AArch64 NEON). The lane width is picked
//! per architecture by `cfg` ([`LANES`]); there is no `unsafe` and no
//! intrinsic call, keeping the crate's zero-`unsafe` invariant.
//!
//! Two invariants pin the backend to the scalar reference (both
//! property-tested here and across the whole tuner candidate space in
//! [`super::plan`]):
//!
//! 1. **Bit-exactness** — i16×i16→i32 products accumulated in i32 are
//!    order-independent (integer addition is associative and commutative,
//!    and the magnitudes involved cannot overflow i32), so lane-parallel
//!    accumulation produces the same logits as the sequential scalar
//!    loops, requantization included.
//! 2. **Event-stream identity** — the modeled MCU micro-op stream is a
//!    function of shapes only, so each vec kernel emits the *aggregate*
//!    of the events its scalar twin interleaves with compute (see
//!    [`mm_events`]). The [`crate::mcu`] cost model therefore prices a
//!    `VecLanes` schedule identically to its `ScalarRef` twin: only the
//!    *host* wall-clock changes, which is exactly what the
//!    `obs::drift` monitor and `benches/infer_hot.rs` measure.

use crate::quant::{requantize, sat_i8};

use super::conv::QuantConv;
use super::depthwise::QuantDepthwise;
use super::im2col::{
    fill_patch_q15, mat_mult_1x1, mat_mult_1x2, mat_mult_2x1, mat_mult_2x2,
};
use super::monitor::Monitor;
use super::ops::QuantDense;
use super::plan::MAX_BLOCK;
use super::shift::ShiftConv;
use super::tensor::Tensor;

/// Host execution backend for a compiled kernel.
///
/// `ScalarRef` is the reference implementation every numerical claim is
/// pinned against; `VecLanes` is the autovectorizer-friendly lane
/// backend in this module. Both produce identical logits and identical
/// modeled MCU event streams — the tuner's analytic scores do not depend
/// on the backend, so the axis only changes measured host throughput.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Backend {
    /// Scalar reference loops (the default; bit-exactness oracle).
    #[default]
    ScalarRef,
    /// Fixed-width i16 lane loops (host-vectorized).
    VecLanes,
}

impl Backend {
    /// CLI / cache-file spelling.
    pub fn as_str(&self) -> &'static str {
        match self {
            Backend::ScalarRef => "scalar",
            Backend::VecLanes => "vec",
        }
    }

    /// Parse the CLI / cache-file spelling.
    pub fn parse(s: &str) -> Result<Backend, String> {
        match s {
            "scalar" => Ok(Backend::ScalarRef),
            "vec" => Ok(Backend::VecLanes),
            other => Err(format!("unknown backend '{other}' (scalar|vec)")),
        }
    }
}

/// i16 lane width of the vec backend on this architecture: 8 lanes where
/// a 128-bit integer unit is baseline (one `pmaddwd`/`smlal2` feeds all
/// eight 16-bit lanes), 4 elsewhere so the fallback still fits a 64-bit
/// datapath.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
pub const LANES: usize = 8;
/// i16 lane width of the vec backend on this architecture (see the
/// x86-64/AArch64 definition).
#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
pub const LANES: usize = 4;

/// Fixed-width i32 accumulator block — the lane struct the whole backend
/// is built from. Keeping the accumulators in one `[i32; LANES]` value
/// (instead of a running scalar) removes the loop-carried dependency
/// that blocks autovectorization of dot products.
#[derive(Clone, Copy)]
struct AccLanes([i32; LANES]);

impl AccLanes {
    #[inline(always)]
    fn zero() -> Self {
        AccLanes([0; LANES])
    }

    /// Lane-wise multiply-accumulate of one `LANES`-wide q15 block.
    #[inline(always)]
    fn madd(&mut self, w: &[i16], c: &[i16]) {
        debug_assert!(w.len() == LANES && c.len() == LANES);
        for ((a, &wv), &cv) in self.0.iter_mut().zip(w).zip(c) {
            *a += wv as i32 * cv as i32;
        }
    }

    /// Horizontal sum of the lanes.
    #[inline(always)]
    fn sum(&self) -> i32 {
        self.0.iter().sum()
    }
}

/// Lane dot product of two q15 rows (`chunks_exact(LANES)` body + scalar
/// remainder). Bit-exact with the sequential scalar sum for all q15
/// operands this engine produces (see the module docs).
#[inline]
pub(crate) fn dot_q15(w: &[i16], c: &[i16]) -> i32 {
    debug_assert_eq!(w.len(), c.len());
    let mut lanes = AccLanes::zero();
    let mut wi = w.chunks_exact(LANES);
    let mut ci = c.chunks_exact(LANES);
    for (wb, cb) in (&mut wi).zip(&mut ci) {
        lanes.madd(wb, cb);
    }
    let mut acc = lanes.sum();
    for (&wv, &cv) in wi.remainder().iter().zip(ci.remainder()) {
        acc += wv as i32 * cv as i32;
    }
    acc
}

/// Emit the aggregate modeled-MCU event stream of an `R×C`-block matmul
/// over a length-`k` reduction — the closed form every
/// `im2col::mat_mult_*` kernel and [`super::blocking::mat_mult_block_into`]
/// interleave with their compute (`R` filter rows, `C` im2col columns):
/// per 4-wide `__SMLAD` block one q7x4 word per row (`+ 2×SXTB16`), two
/// q15 words per column and `2RC` SMLADs; per scalar-tail element one
/// q7 byte per row, one q15 half per column and `RC` MACs.
pub(crate) fn mm_events<M: Monitor>(rows: usize, cols: usize, k: usize, mon: &mut M) {
    let k4 = (k / 4) as u64;
    let tail = (k - (k / 4) * 4) as u64;
    let (r, c) = (rows as u64, cols as u64);
    mon.ld32(r + k4 * (r + 2 * c)); // bias words + per-block row/column words
    mon.alu(2 * r * k4); // SXTB16 widening
    mon.smlad(2 * r * c * k4);
    mon.branch(k4 + tail);
    mon.ld8(r * tail);
    mon.ld16(c * tail);
    mon.mac(r * c * tail);
}

/// The 2×2-family matmul micro-kernels behind the shift and dense SIMD
/// loop structure, abstracted so one loop body serves both backends:
/// [`ScalarMm`] delegates to the event-interleaved `im2col::mat_mult_*`
/// reference kernels, [`VecMm`] emits the same events in aggregate
/// ([`mm_events`]) and computes with [`dot_q15`] lanes.
pub(crate) trait Mm {
    fn m2x2<M: Monitor>(
        wa: &[i16],
        wb: &[i16],
        pa: &[i16],
        pb: &[i16],
        bias_a: i32,
        bias_b: i32,
        mon: &mut M,
    ) -> [i32; 4];
    fn m1x2<M: Monitor>(w: &[i16], pa: &[i16], pb: &[i16], bias: i32, mon: &mut M) -> [i32; 2];
    fn m2x1<M: Monitor>(
        wa: &[i16],
        wb: &[i16],
        p: &[i16],
        bias_a: i32,
        bias_b: i32,
        mon: &mut M,
    ) -> [i32; 2];
    fn m1x1<M: Monitor>(w: &[i16], p: &[i16], bias: i32, mon: &mut M) -> i32;
}

/// [`Mm`] backed by the scalar reference kernels.
pub(crate) struct ScalarMm;

impl Mm for ScalarMm {
    #[inline(always)]
    fn m2x2<M: Monitor>(
        wa: &[i16],
        wb: &[i16],
        pa: &[i16],
        pb: &[i16],
        bias_a: i32,
        bias_b: i32,
        mon: &mut M,
    ) -> [i32; 4] {
        mat_mult_2x2(wa, wb, pa, pb, bias_a, bias_b, mon)
    }

    #[inline(always)]
    fn m1x2<M: Monitor>(w: &[i16], pa: &[i16], pb: &[i16], bias: i32, mon: &mut M) -> [i32; 2] {
        mat_mult_1x2(w, pa, pb, bias, mon)
    }

    #[inline(always)]
    fn m2x1<M: Monitor>(
        wa: &[i16],
        wb: &[i16],
        p: &[i16],
        bias_a: i32,
        bias_b: i32,
        mon: &mut M,
    ) -> [i32; 2] {
        mat_mult_2x1(wa, wb, p, bias_a, bias_b, mon)
    }

    #[inline(always)]
    fn m1x1<M: Monitor>(w: &[i16], p: &[i16], bias: i32, mon: &mut M) -> i32 {
        mat_mult_1x1(w, p, bias, mon)
    }
}

/// [`Mm`] backed by the lane kernels.
pub(crate) struct VecMm;

impl Mm for VecMm {
    #[inline(always)]
    fn m2x2<M: Monitor>(
        wa: &[i16],
        wb: &[i16],
        pa: &[i16],
        pb: &[i16],
        bias_a: i32,
        bias_b: i32,
        mon: &mut M,
    ) -> [i32; 4] {
        mm_events(2, 2, wa.len(), mon);
        [
            bias_a + dot_q15(wa, pa),
            bias_a + dot_q15(wa, pb),
            bias_b + dot_q15(wb, pa),
            bias_b + dot_q15(wb, pb),
        ]
    }

    #[inline(always)]
    fn m1x2<M: Monitor>(w: &[i16], pa: &[i16], pb: &[i16], bias: i32, mon: &mut M) -> [i32; 2] {
        mm_events(1, 2, w.len(), mon);
        [bias + dot_q15(w, pa), bias + dot_q15(w, pb)]
    }

    #[inline(always)]
    fn m2x1<M: Monitor>(
        wa: &[i16],
        wb: &[i16],
        p: &[i16],
        bias_a: i32,
        bias_b: i32,
        mon: &mut M,
    ) -> [i32; 2] {
        mm_events(2, 1, wa.len(), mon);
        [bias_a + dot_q15(wa, p), bias_b + dot_q15(wb, p)]
    }

    #[inline(always)]
    fn m1x1<M: Monitor>(w: &[i16], p: &[i16], bias: i32, mon: &mut M) -> i32 {
        mm_events(1, 1, w.len(), mon);
        bias + dot_q15(w, p)
    }
}

/// Lane twin of [`super::blocking::mat_mult_block_into`]: `F` pre-widened
/// q15 filter rows against `P` q15 im2col columns. Event stream and
/// results are identical to the scalar kernel; the compute is `F·P`
/// independent [`dot_q15`] lane reductions instead of one interleaved
/// `f·p`-accumulator loop.
pub fn mat_mult_block_vec_into<M: Monitor>(
    w_rows: &[&[i16]],
    cols: &[&[i16]],
    biases: &[i32],
    acc: &mut [i32],
    mon: &mut M,
) {
    let f = w_rows.len();
    let p = cols.len();
    assert_eq!(biases.len(), f, "one bias per filter row");
    assert_eq!(acc.len(), f * p, "f·p accumulators");
    let k = w_rows[0].len();
    debug_assert!(w_rows.iter().all(|r| r.len() == k));
    debug_assert!(cols.iter().all(|c| c.len() == k));

    mm_events(f, p, k, mon);
    for (fi, (w, &b)) in w_rows.iter().zip(biases).enumerate() {
        for (pi, c) in cols.iter().enumerate() {
            acc[fi * p + pi] = b + dot_q15(w, c);
        }
    }
}

/// Lane twin of [`super::plan::conv_blocked_into`] — identical blocking
/// structure, `fill_patch_q15` gathers and epilogue, with the inner
/// matmul swapped for [`mat_mult_block_vec_into`] over pre-widened q15
/// weight rows (`wq`, one i16 per q7 weight, as assembled at deploy
/// time by `ExecPlan`).
#[allow(clippy::too_many_arguments)]
pub fn conv_blocked_vec_into<M: Monitor>(
    conv: &QuantConv,
    x: &Tensor,
    y: &mut Tensor,
    p_blk: usize,
    f_blk: usize,
    cols: &mut [i16],
    acc: &mut [i32],
    wq: &[i16],
    mon: &mut M,
) {
    assert!(p_blk >= 1 && f_blk >= 1, "degenerate blocking");
    assert!(
        p_blk <= MAX_BLOCK && f_blk <= MAX_BLOCK,
        "blocking ({p_blk},{f_blk}) beyond the provisioned maximum {MAX_BLOCK}"
    );
    conv.validate(&x.shape).expect("invalid conv configuration");
    debug_assert_eq!(wq.len(), conv.weights.len(), "pre-widened weight length");
    let out_shape = conv.output_shape(&x.shape);
    debug_assert_eq!(y.shape, out_shape, "output buffer shape mismatch");
    debug_assert_eq!(y.q, conv.q_out, "output buffer format mismatch");
    let shift = conv.out_shift();
    let cpg = conv.ch_per_group();
    let fpg = conv.filters_per_group();
    let klen = conv.kernel * conv.kernel * cpg;
    debug_assert!(cols.len() >= p_blk * klen, "column arena too small");
    debug_assert!(acc.len() >= p_blk * f_blk, "accumulator arena too small");
    let n_pix = out_shape.h * out_shape.w;

    for g in 0..conv.groups {
        let ch0 = g * cpg;
        let n0 = g * fpg;
        let mut pix = 0usize;
        while pix < n_pix {
            let pcnt = p_blk.min(n_pix - pix);
            for (pi, col) in cols.chunks_mut(klen).take(pcnt).enumerate() {
                let (oy, ox) = ((pix + pi) / out_shape.w, (pix + pi) % out_shape.w);
                fill_patch_q15(x, oy, ox, conv.kernel, conv.pad, ch0, cpg, col, mon);
            }
            let mut col_refs: [&[i16]; MAX_BLOCK] = [&[]; MAX_BLOCK];
            for (pi, col) in cols.chunks(klen).take(pcnt).enumerate() {
                col_refs[pi] = col;
            }
            let mut f0 = 0usize;
            while f0 < fpg {
                let fcnt = f_blk.min(fpg - f0);
                let mut w_rows: [&[i16]; MAX_BLOCK] = [&[]; MAX_BLOCK];
                let mut biases = [0i32; MAX_BLOCK];
                for fi in 0..fcnt {
                    let n = n0 + f0 + fi;
                    w_rows[fi] = &wq[n * klen..(n + 1) * klen];
                    biases[fi] = conv.bias[n];
                }
                mat_mult_block_vec_into(
                    &w_rows[..fcnt],
                    &col_refs[..pcnt],
                    &biases[..fcnt],
                    &mut acc[..fcnt * pcnt],
                    mon,
                );
                for fi in 0..fcnt {
                    let n = n0 + f0 + fi;
                    for pi in 0..pcnt {
                        let (oy, ox) = ((pix + pi) / out_shape.w, (pix + pi) % out_shape.w);
                        mon.alu(2);
                        mon.st8(1);
                        y.set(oy, ox, n, sat_i8(requantize(acc[fi * pcnt + pi], shift)));
                    }
                }
                f0 += fcnt;
            }
            pix += pcnt;
        }
    }
}

/// Reorder a depthwise layer's `[channels][k][k]` q7 weights into
/// channel-minor `[k][k][channels]` q15 — one contiguous lane run per
/// tap, mirroring the CMSIS-NN offline reorder the modeled SIMD kernel
/// assumes. Assembled once at deploy time (`ExecPlan` weight prep).
pub fn depthwise_wq(d: &QuantDepthwise) -> Vec<i16> {
    let (k, ch) = (d.kernel, d.channels);
    let mut wq = vec![0i16; d.weights.len()];
    for c in 0..ch {
        for i in 0..k {
            for j in 0..k {
                wq[(i * k + j) * ch + c] = d.weights[(c * k + i) * k + j] as i16;
            }
        }
    }
    wq
}

/// Lane twin of [`QuantDepthwise::forward_simd_into`]: per output pixel
/// the whole channel axis is accumulated as contiguous lane runs (HWC
/// activations × the [`depthwise_wq`] tap-major weights), with the
/// modeled per-tap event stream emitted in aggregate. `acc` is the
/// per-channel i32 accumulator scratch (`channels` long, lives in the
/// workspace arena).
pub fn depthwise_vec_into<M: Monitor>(
    d: &QuantDepthwise,
    x: &Tensor,
    y: &mut Tensor,
    wq: &[i16],
    acc: &mut [i32],
    mon: &mut M,
) {
    d.validate(&x.shape).expect("invalid depthwise configuration");
    let out_shape = d.output_shape(&x.shape);
    debug_assert_eq!(y.shape, out_shape, "output buffer shape mismatch");
    debug_assert_eq!(y.q, d.q_out, "output buffer format mismatch");
    debug_assert_eq!(wq.len(), d.weights.len(), "reordered weight length");
    let ch = d.channels;
    debug_assert!(acc.len() >= ch, "accumulator arena too small");
    let shift = d.out_shift();
    let k = d.kernel;
    let pad = d.pad as isize;
    let c4 = (ch / 4) as u64;
    let rem = (ch % 4) as u64;

    for oy in 0..out_shape.h {
        for ox in 0..out_shape.w {
            // aggregate of the events the channel-blocked scalar kernel
            // interleaves per pixel: taps = in-bounds (i, j) positions
            let rows_in = (0..k)
                .filter(|&i| {
                    let iy = oy as isize + i as isize - pad;
                    iy >= 0 && iy < x.shape.h as isize
                })
                .count() as u64;
            let cols_in = (0..k)
                .filter(|&j| {
                    let ix = ox as isize + j as isize - pad;
                    ix >= 0 && ix < x.shape.w as isize
                })
                .count() as u64;
            let rows_oob = k as u64 - rows_in;
            let taps = rows_in * cols_in;
            mon.ld32(2 * c4 + rem + taps * 2 * c4);
            mon.branch((rows_oob + rows_in * k as u64) * (c4 + rem));
            mon.alu(taps * 4 * c4 + 2 * ch as u64);
            mon.mac(taps * (4 * c4 + rem));
            mon.ld8(taps * 2 * rem);
            mon.st8(ch as u64);

            // lane compute: bias init, one contiguous channel run per tap
            let accs = &mut acc[..ch];
            accs.copy_from_slice(&d.bias);
            for i in 0..k {
                let iy = oy as isize + i as isize - pad;
                if iy < 0 || iy >= x.shape.h as isize {
                    continue;
                }
                for j in 0..k {
                    let ix = ox as isize + j as isize - pad;
                    if ix < 0 || ix >= x.shape.w as isize {
                        continue;
                    }
                    let xs = &x.data[x.shape.idx(iy as usize, ix as usize, 0)..][..ch];
                    let ws = &wq[(i * k + j) * ch..][..ch];
                    for ((a, &xv), &wv) in accs.iter_mut().zip(xs).zip(ws) {
                        *a += xv as i32 * wv as i32;
                    }
                }
            }
            for (c, &a) in accs.iter().enumerate() {
                y.set(oy, ox, c, sat_i8(requantize(a, shift)));
            }
        }
    }
}

/// Lane twin of [`ShiftConv::forward_simd_with`] — same shifted-gather
/// im2col loop structure, inner matmuls swapped for [`VecMm`].
#[allow(clippy::too_many_arguments)]
pub fn shift_vec_with<M: Monitor>(
    s: &ShiftConv,
    x: &Tensor,
    y: &mut Tensor,
    col_a: &mut [i16],
    col_b: &mut [i16],
    wq: &[i16],
    mon: &mut M,
) {
    s.forward_simd_mm::<VecMm, M>(x, y, col_a, col_b, wq, mon)
}

/// Lane twin of [`QuantDense::forward_simd_with`] — same widen-once +
/// row-pair loop structure, inner matmuls swapped for [`VecMm`].
pub fn dense_vec_with<M: Monitor>(
    d: &QuantDense,
    x: &[i8],
    out: &mut [i8],
    xq: &mut [i16],
    wq: &[i16],
    mon: &mut M,
) {
    d.forward_simd_mm::<VecMm, M>(x, out, xq, wq, mon)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::conv::test_random_conv;
    use crate::nn::monitor::CountingMonitor;
    use crate::nn::ops::QuantDense;
    use crate::nn::plan::conv_blocked_into;
    use crate::nn::shift::test_random_shift_conv;
    use crate::nn::tensor::{Shape, Tensor};
    use crate::quant::QParam;
    use crate::util::prng::Rng;
    use crate::util::prop::{check, ensure, ensure_eq_i8};

    fn random_input(rng: &mut Rng, h: usize, c: usize) -> Tensor {
        let mut t = Tensor::zeros(Shape::new(h, h, c), QParam::new(7));
        rng.fill_i8(&mut t.data, -16, 16);
        t
    }

    fn random_depthwise(rng: &mut Rng, k: usize, c: usize) -> QuantDepthwise {
        let mut weights = vec![0i8; c * k * k];
        rng.fill_i8(&mut weights, -8, 8);
        QuantDepthwise {
            kernel: k,
            channels: c,
            pad: k / 2,
            weights,
            bias: (0..c).map(|_| rng.range(0, 32) as i32 - 16).collect(),
            q_in: QParam::new(7),
            q_w: QParam::new(7),
            q_out: QParam::new(5),
        }
    }

    fn widen(w: &[i8]) -> Vec<i16> {
        w.iter().map(|&v| v as i16).collect()
    }

    #[test]
    fn backend_spelling_roundtrips() {
        for b in [Backend::ScalarRef, Backend::VecLanes] {
            assert_eq!(Backend::parse(b.as_str()), Ok(b));
        }
        assert!(Backend::parse("neon").is_err());
        assert_eq!(Backend::default(), Backend::ScalarRef);
    }

    #[test]
    fn dot_matches_sequential_sum_across_remainder_lengths() {
        let mut rng = Rng::new(31);
        for len in [0usize, 1, 3, 4, 7, 8, 9, 15, 16, 17, 31, 64, 100] {
            let mut w8 = vec![0i8; len];
            let mut c8 = vec![0i8; len];
            rng.fill_i8(&mut w8, -128, 127);
            rng.fill_i8(&mut c8, -128, 127);
            let w = widen(&w8);
            let c = widen(&c8);
            let naive: i32 = w.iter().zip(&c).map(|(&a, &b)| a as i32 * b as i32).sum();
            assert_eq!(dot_q15(&w, &c), naive, "len {len}");
        }
    }

    #[test]
    fn vec_mm_kernels_match_scalar_reference_events_included() {
        check(
            "vec-mm-vs-scalar",
            64,
            |rng, _| {
                let k = rng.range(1, 40);
                let mut buf = vec![0i8; 4 * k];
                rng.fill_i8(&mut buf, -64, 64);
                let rows: Vec<i16> = widen(&buf);
                (rows, k, rng.range(0, 64) as i32 - 32)
            },
            |(buf, k, bias)| {
                let (wa, rest) = buf.split_at(*k);
                let (wb, rest) = rest.split_at(*k);
                let (pa, pb) = rest.split_at(*k);
                let (b0, b1) = (*bias, -bias);
                let mut ms = CountingMonitor::new();
                let mut mv = CountingMonitor::new();
                let s22 = ScalarMm::m2x2(wa, wb, pa, pb, b0, b1, &mut ms);
                let v22 = VecMm::m2x2(wa, wb, pa, pb, b0, b1, &mut mv);
                ensure(s22 == v22, "2x2 accs differ")?;
                ensure(ms.counts == mv.counts, "2x2 event streams differ")?;
                let mut ms = CountingMonitor::new();
                let mut mv = CountingMonitor::new();
                ensure(
                    ScalarMm::m1x2(wa, pa, pb, b0, &mut ms)
                        == VecMm::m1x2(wa, pa, pb, b0, &mut mv),
                    "1x2 accs differ",
                )?;
                ensure(ms.counts == mv.counts, "1x2 event streams differ")?;
                let mut ms = CountingMonitor::new();
                let mut mv = CountingMonitor::new();
                ensure(
                    ScalarMm::m2x1(wa, wb, pa, b0, b1, &mut ms)
                        == VecMm::m2x1(wa, wb, pa, b0, b1, &mut mv),
                    "2x1 accs differ",
                )?;
                ensure(ms.counts == mv.counts, "2x1 event streams differ")?;
                let mut ms = CountingMonitor::new();
                let mut mv = CountingMonitor::new();
                ensure(
                    ScalarMm::m1x1(wa, pa, b0, &mut ms) == VecMm::m1x1(wa, pa, b0, &mut mv),
                    "1x1 accs differ",
                )?;
                ensure(ms.counts == mv.counts, "1x1 event streams differ")
            },
        );
    }

    #[test]
    fn blocked_conv_vec_is_bit_exact_and_event_identical() {
        check(
            "conv-blocked-vec-vs-scalar",
            48,
            |rng, _| {
                let groups = [1usize, 2][rng.range(0, 1)];
                let cin = groups * rng.range(1, 6);
                let cout = groups * rng.range(1, 6);
                let k = [1usize, 3][rng.range(0, 1)];
                let h = rng.range(k, k + 4);
                let (p, f) = (rng.range(1, MAX_BLOCK), rng.range(1, MAX_BLOCK));
                (test_random_conv(rng, groups, k, cin, cout), random_input(rng, h, cin), p, f)
            },
            |(conv, x, p, f)| {
                let klen = conv.kernel * conv.kernel * conv.ch_per_group();
                let mut cols = vec![0i16; p * klen];
                let mut acc = vec![0i32; p * f];
                let mut ys = Tensor::zeros(conv.output_shape(&x.shape), conv.q_out);
                let mut yv = ys.clone();
                let mut ms = CountingMonitor::new();
                let mut mv = CountingMonitor::new();
                conv_blocked_into(conv, x, &mut ys, *p, *f, &mut cols, &mut acc, &mut ms);
                let wq = widen(&conv.weights);
                conv_blocked_vec_into(
                    conv, x, &mut yv, *p, *f, &mut cols, &mut acc, &wq, &mut mv,
                );
                ensure_eq_i8(&ys.data, &yv.data, "blocked conv vec vs scalar")?;
                ensure(ms.counts == mv.counts, "blocked conv event streams differ")
            },
        );
    }

    #[test]
    fn depthwise_vec_is_bit_exact_and_event_identical_on_lane_remainders() {
        // channel counts straddling both the modeled 4-channel blocking
        // and the host LANES width, remainders included
        check(
            "depthwise-vec-vs-scalar",
            48,
            |rng, _| {
                let c = [1usize, 3, 4, 5, 7, 8, 9, 13, 16][rng.range(0, 8)];
                let k = [1usize, 3, 5][rng.range(0, 2)];
                let h = rng.range(k, k + 4);
                (random_depthwise(rng, k, c), random_input(rng, h, c))
            },
            |(dw, x)| {
                let mut ys = Tensor::zeros(dw.output_shape(&x.shape), dw.q_out);
                let mut yv = ys.clone();
                let mut ms = CountingMonitor::new();
                let mut mv = CountingMonitor::new();
                dw.forward_simd_into(x, &mut ys, &mut ms);
                let wq = depthwise_wq(dw);
                let mut acc = vec![0i32; dw.channels];
                depthwise_vec_into(dw, x, &mut yv, &wq, &mut acc, &mut mv);
                ensure_eq_i8(&ys.data, &yv.data, "depthwise vec vs scalar")?;
                ensure(ms.counts == mv.counts, "depthwise event streams differ")
            },
        );
    }

    #[test]
    fn shift_vec_is_bit_exact_and_event_identical() {
        check(
            "shift-vec-vs-scalar",
            32,
            |rng, _| {
                let cin = rng.range(1, 12);
                let cout = rng.range(1, 12);
                let h = rng.range(2, 6);
                (test_random_shift_conv(rng, cin, cout, 3), random_input(rng, h, cin))
            },
            |(sc, x)| {
                let klen = sc.in_channels;
                let (mut ca, mut cb) = (vec![0i16; klen], vec![0i16; klen]);
                let wq = widen(&sc.weights);
                let mut ys = Tensor::zeros(sc.output_shape(&x.shape), sc.q_out);
                let mut yv = ys.clone();
                let mut ms = CountingMonitor::new();
                let mut mv = CountingMonitor::new();
                sc.forward_simd_with(x, &mut ys, &mut ca, &mut cb, &wq, &mut ms);
                shift_vec_with(sc, x, &mut yv, &mut ca, &mut cb, &wq, &mut mv);
                ensure_eq_i8(&ys.data, &yv.data, "shift vec vs scalar")?;
                ensure(ms.counts == mv.counts, "shift event streams differ")
            },
        );
    }

    #[test]
    fn dense_vec_is_bit_exact_and_event_identical() {
        check(
            "dense-vec-vs-scalar",
            32,
            |rng, _| {
                let (fin, fout) = (rng.range(1, 40), rng.range(1, 12));
                let mut weights = vec![0i8; fin * fout];
                rng.fill_i8(&mut weights, -16, 16);
                let d = QuantDense {
                    in_features: fin,
                    out_features: fout,
                    weights,
                    bias: (0..fout).map(|_| rng.range(0, 32) as i32 - 16).collect(),
                    q_in: QParam::new(7),
                    q_w: QParam::new(7),
                    q_out: QParam::new(5),
                };
                let mut x = vec![0i8; fin];
                rng.fill_i8(&mut x, -32, 32);
                (d, x)
            },
            |(d, x)| {
                let wq = widen(&d.weights);
                let mut xq = vec![0i16; d.in_features];
                let mut outs = vec![0i8; d.out_features];
                let mut outv = vec![0i8; d.out_features];
                let mut ms = CountingMonitor::new();
                let mut mv = CountingMonitor::new();
                d.forward_simd_with(x, &mut outs, &mut xq, &wq, &mut ms);
                dense_vec_with(d, x, &mut outv, &mut xq, &wq, &mut mv);
                ensure_eq_i8(&outs, &outv, "dense vec vs scalar")?;
                ensure(ms.counts == mv.counts, "dense event streams differ")
            },
        );
    }

    #[test]
    fn depthwise_weight_reorder_is_a_permutation() {
        let mut rng = Rng::new(9);
        let d = random_depthwise(&mut rng, 3, 5);
        let wq = depthwise_wq(&d);
        assert_eq!(wq.len(), d.weights.len());
        for c in 0..d.channels {
            for i in 0..d.kernel {
                for j in 0..d.kernel {
                    assert_eq!(
                        wq[(i * d.kernel + j) * d.channels + c],
                        d.weights[(c * d.kernel + i) * d.kernel + j] as i16
                    );
                }
            }
        }
    }
}
