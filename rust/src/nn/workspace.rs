//! Per-model scratch arena for allocation-free inference.
//!
//! [`Model::forward`] heap-allocates on every call: one fresh
//! `Tensor::zeros` per layer, two im2col columns per SIMD convolution,
//! the widened `wq` weight copy, the shift-conv intermediate map. A
//! [`Workspace`] hoists all of that into state planned once at deploy
//! time, so [`Model::forward_in`] performs **zero heap allocations** in
//! steady state (pinned by `benches/infer_hot.rs` with a counting global
//! allocator):
//!
//! * two ping-pong activation buffers sized to the largest activation of
//!   the model (NNoM's layer-buffer scheme);
//! * the two q15 im2col column slots of the widest layer (the paper's
//!   2-patch cap is exactly what bounds them);
//! * per-layer pre-widened q15 weights for the SIMD matmuls (widened once
//!   per deployed model instead of once per call);
//! * the shift-convolution intermediate map `I` (Eq. 2) for the scalar
//!   path.
//!
//! Because every byte is planned up front, the [`WorkspacePlan`] doubles
//! as an **exact** peak-RAM report for the deployment — the quantity
//! `mcu::footprint` estimates and the paper's §3.3 memory-footprint
//! discussion bounds.
//!
//! Event streams are untouched: `forward_in` drives the same kernels
//! through their `*_into` / `*_with` entry points, so outputs are
//! bit-exact with [`Model::forward`] and a [`CountingMonitor`] sees the
//! identical micro-op mix (both properties are tested below, including
//! reuse of a dirty workspace).

use crate::quant::QParam;
use crate::util::fnv::Fnv1a;

use super::graph::{Layer, LayerProfile, Model};
use super::monitor::{CountingMonitor, Monitor};
use super::ops;
use super::tensor::{Shape, Tensor};

/// Byte-exact breakdown of a planned arena — the deployment's peak-RAM
/// report. All quantities are bytes.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WorkspacePlan {
    /// The two ping-pong activation buffers (each sized to the largest
    /// activation, input included).
    pub activation_bytes: usize,
    /// Largest input+output activation pair — the tight lower bound an
    /// in-place ping-pong deployment must provision (`mcu::footprint`'s
    /// estimate of the same quantity).
    pub peak_pair_bytes: usize,
    /// Shift-convolution intermediate map `I` (scalar path), sized to the
    /// largest shift-layer input.
    pub shift_scratch_bytes: usize,
    /// The two q15 im2col / gather / widen columns of the widest layer.
    pub im2col_bytes: usize,
    /// Pre-widened q15 weight copies for the SIMD matmul layers.
    pub widened_weight_bytes: usize,
}

impl WorkspacePlan {
    /// Total arena bytes held at run time (weights in flash excluded;
    /// the widened copies are SRAM on our host-side engine).
    pub fn total_bytes(&self) -> usize {
        self.activation_bytes
            + self.shift_scratch_bytes
            + self.im2col_bytes
            + self.widened_weight_bytes
    }

    /// One-line report for logs and CLI output.
    pub fn summary(&self) -> String {
        format!(
            "arena {} B (activations {} B [peak pair {} B], im2col {} B, \
             shift scratch {} B, widened weights {} B)",
            self.total_bytes(),
            self.activation_bytes,
            self.peak_pair_bytes,
            self.im2col_bytes,
            self.shift_scratch_bytes,
            self.widened_weight_bytes
        )
    }
}

/// Reshape a tensor in place without allocating (the target length must
/// be within the capacity planned for it).
#[inline]
fn prepare(t: &mut Tensor, shape: Shape, q: QParam) {
    debug_assert!(
        shape.len() <= t.data.capacity(),
        "workspace buffer capacity {} < required {}",
        t.data.capacity(),
        shape.len()
    );
    t.shape = shape;
    t.q = q;
    t.data.resize(shape.len(), 0);
}

fn tensor_with_capacity(cap: usize, q: QParam) -> Tensor {
    Tensor {
        shape: Shape::new(0, 0, 0),
        q,
        data: Vec::with_capacity(cap),
    }
}

fn widen(weights: &[i8]) -> Vec<i16> {
    weights.iter().map(|&w| w as i16).collect()
}

/// FNV-1a fingerprint of every parameter tensor in the model. The arena
/// caches pre-widened weight copies, so reusing it against a model whose
/// weights changed (same name, same shapes — e.g. a recalibrated
/// redeployment) would silently compute with stale weights; the
/// fingerprint turns that into a loud failure. Cost: linear in the
/// parameter count, allocation-free — validated at bind time (and on
/// every call in debug builds, which is what the test suite runs).
fn model_weight_fingerprint(model: &Model) -> u64 {
    let mut h = Fnv1a::new();
    for layer in &model.layers {
        match layer {
            Layer::Conv(c) => {
                h.i8s(&c.weights);
                h.i32s(&c.bias);
            }
            Layer::Depthwise(d) => {
                h.i8s(&d.weights);
                h.i32s(&d.bias);
            }
            Layer::Shift(s) => {
                h.i8s(&s.weights);
                h.i32s(&s.bias);
            }
            Layer::AddConv(a) => {
                h.i8s(&a.weights);
                h.i32s(&a.bias);
            }
            Layer::Bn(b) => {
                h.i16s(&b.m);
                h.i32s(&b.b);
            }
            Layer::Dense(d) => {
                h.i8s(&d.weights);
                h.i32s(&d.bias);
            }
            // parameterless layers still advance the stream so layer
            // reordering changes the fingerprint
            Layer::Relu | Layer::MaxPool2 | Layer::GlobalAvgPool(_) => {
                h.byte(0x9e);
            }
        }
    }
    h.finish()
}

/// The per-model scratch arena. Build once per deployed model (per
/// serving worker); reuse across every inference. Deliberately not
/// `Clone`: `Vec::clone` does not preserve spare capacity, which would
/// silently reintroduce steady-state growth — plan a fresh arena per
/// worker instead.
#[derive(Debug)]
pub struct Workspace {
    /// Name, layer count, input shape and parameter fingerprint of the
    /// model this arena was planned for (guards against cross-model
    /// reuse — including a same-shaped redeployment with different
    /// weights, which would otherwise silently hit the stale pre-widened
    /// copies).
    model_name: String,
    n_layers: usize,
    input_shape: Shape,
    weight_fp: u64,
    /// Ping-pong activation buffers.
    buf_a: Tensor,
    buf_b: Tensor,
    /// Shift-conv scalar intermediate map `I`.
    shift_inter: Tensor,
    /// q15 im2col / gather columns (also the dense input-widening slot).
    col_a: Vec<i16>,
    col_b: Vec<i16>,
    /// Per-layer pre-widened q15 weights (empty where not applicable).
    wq: Vec<Vec<i16>>,
    plan: WorkspacePlan,
}

impl Workspace {
    /// Plan and allocate the arena for `model` (both code paths: the
    /// scalar path needs the shift scratch, the SIMD path the columns
    /// and widened weights).
    pub fn new(model: &Model) -> Self {
        let shapes = model.shapes();
        let max_act = shapes.iter().map(|s| s.len()).max().unwrap_or(0);
        let peak_pair = shapes
            .windows(2)
            .map(|w| w[0].len() + w[1].len())
            .max()
            .unwrap_or(max_act);

        let mut shift_inter_len = 0usize;
        let mut col_len = 0usize;
        let mut wq: Vec<Vec<i16>> = Vec::with_capacity(model.layers.len());
        for (layer, in_shape) in model.layers.iter().zip(&shapes) {
            match layer {
                Layer::Conv(c) => {
                    col_len = col_len.max(c.kernel * c.kernel * c.ch_per_group());
                    wq.push(widen(&c.weights));
                }
                Layer::Shift(s) => {
                    shift_inter_len = shift_inter_len.max(in_shape.len());
                    col_len = col_len.max(s.in_channels);
                    wq.push(widen(&s.weights));
                }
                Layer::Dense(d) => {
                    col_len = col_len.max(d.in_features);
                    wq.push(widen(&d.weights));
                }
                _ => wq.push(Vec::new()),
            }
        }

        let plan = WorkspacePlan {
            activation_bytes: 2 * max_act,
            peak_pair_bytes: peak_pair,
            shift_scratch_bytes: shift_inter_len,
            im2col_bytes: 2 * col_len * 2,
            widened_weight_bytes: 2 * wq.iter().map(|w| w.len()).sum::<usize>(),
        };

        Self {
            model_name: model.name.clone(),
            n_layers: model.layers.len(),
            input_shape: model.input_shape,
            weight_fp: model_weight_fingerprint(model),
            buf_a: tensor_with_capacity(max_act, model.input_q),
            buf_b: tensor_with_capacity(max_act, model.input_q),
            shift_inter: tensor_with_capacity(shift_inter_len, model.input_q),
            col_a: vec![0i16; col_len],
            col_b: vec![0i16; col_len],
            wq,
            plan,
        }
    }

    /// The byte-exact arena plan (the deployment's peak-RAM report).
    pub fn plan(&self) -> WorkspacePlan {
        self.plan
    }

    /// O(1) structural identity: name, layer count, input shape.
    fn fits_structurally(&self, model: &Model) -> bool {
        self.model_name == model.name
            && self.n_layers == model.layers.len()
            && self.input_shape == model.input_shape
    }

    /// Whether this arena was planned for `model` — structure **and**
    /// parameter values ([`model_weight_fingerprint`], O(params) but
    /// allocation-free). Call this when *binding* a workspace to a model
    /// (the server does at worker spawn); the per-inference path checks
    /// structure every call and re-validates the fingerprint only in
    /// debug builds, so the release hot path pays O(1).
    pub fn fits(&self, model: &Model) -> bool {
        self.fits_structurally(model) && self.weight_fp == model_weight_fingerprint(model)
    }

    /// Execute one layer from the current ping-pong slot into the other,
    /// entirely inside the arena. `cur_is_a` names the slot holding the
    /// layer's input; `idx` is the layer index (for the pre-widened
    /// weights). Identical event stream to [`Layer::forward`].
    fn run_layer<M: Monitor>(
        &mut self,
        layer: &Layer,
        idx: usize,
        cur_is_a: bool,
        simd: bool,
        mon: &mut M,
    ) {
        let (xb, yb) = if cur_is_a {
            (&self.buf_a, &mut self.buf_b)
        } else {
            (&self.buf_b, &mut self.buf_a)
        };
        let out_shape = layer.output_shape(&xb.shape);
        let out_q = layer.output_q(xb.q);
        prepare(yb, out_shape, out_q);
        match layer {
            Layer::Conv(c) => {
                if simd {
                    let klen = c.kernel * c.kernel * c.ch_per_group();
                    c.forward_simd_with(
                        xb,
                        yb,
                        &mut self.col_a[..klen],
                        &mut self.col_b[..klen],
                        &self.wq[idx],
                        mon,
                    );
                } else {
                    c.forward_scalar_into(xb, yb, mon);
                }
            }
            Layer::Depthwise(d) => {
                if simd {
                    d.forward_simd_into(xb, yb, mon);
                } else {
                    d.forward_scalar_into(xb, yb, mon);
                }
            }
            Layer::Shift(s) => {
                if simd {
                    let klen = s.in_channels;
                    s.forward_simd_with(
                        xb,
                        yb,
                        &mut self.col_a[..klen],
                        &mut self.col_b[..klen],
                        &self.wq[idx],
                        mon,
                    );
                } else {
                    prepare(&mut self.shift_inter, xb.shape, xb.q);
                    s.forward_scalar_into(xb, yb, &mut self.shift_inter, mon);
                }
            }
            // add-convolution has no SIMD variant (§3.3)
            Layer::AddConv(a) => a.forward_scalar_into(xb, yb, mon),
            Layer::Bn(b) => b.forward_into(xb, yb, mon),
            Layer::Relu => ops::relu_into(xb, yb, mon),
            Layer::MaxPool2 => ops::maxpool2_into(xb, yb, mon),
            Layer::GlobalAvgPool(qo) => ops::global_avgpool_into(xb, *qo, yb, mon),
            Layer::Dense(d) => {
                if simd {
                    d.forward_simd_with(
                        &xb.data,
                        &mut yb.data,
                        &mut self.col_a[..d.in_features],
                        &self.wq[idx],
                        mon,
                    );
                } else {
                    d.forward_scalar_into(&xb.data, &mut yb.data, mon);
                }
            }
        }
    }

    /// Stage the model input into the first ping-pong slot (the analogue
    /// of `Model::forward`'s initial clone — not a counted event).
    /// Structural identity is asserted on every call; the full parameter
    /// fingerprint (stale pre-widened weights after a same-shaped
    /// redeploy) is re-asserted in debug builds — release callers
    /// validate at bind time via [`Workspace::fits`].
    fn stage_input(&mut self, model: &Model, x: &Tensor) {
        assert_eq!(x.shape, model.input_shape, "model input shape mismatch");
        let ok = if cfg!(debug_assertions) {
            self.fits(model)
        } else {
            self.fits_structurally(model)
        };
        assert!(
            ok,
            "workspace was planned for model {:?}, not {:?} (stale parameters?)",
            self.model_name,
            model.name
        );
        prepare(&mut self.buf_a, x.shape, x.q);
        self.buf_a.data.copy_from_slice(&x.data);
    }
}

impl Model {
    /// Run an inference inside a pre-planned [`Workspace`]: bit-exact
    /// with [`Model::forward`], identical micro-op event stream, zero
    /// heap allocations in steady state. The returned reference points
    /// into the workspace's output buffer and is valid until the next
    /// `forward_in` call.
    pub fn forward_in<'w, M: Monitor>(
        &self,
        x: &Tensor,
        simd: bool,
        ws: &'w mut Workspace,
        mon: &mut M,
    ) -> &'w Tensor {
        ws.stage_input(self, x);
        let mut cur_is_a = true;
        for (idx, layer) in self.layers.iter().enumerate() {
            ws.run_layer(layer, idx, cur_is_a, simd, mon);
            cur_is_a = !cur_is_a;
        }
        if cur_is_a {
            &ws.buf_a
        } else {
            &ws.buf_b
        }
    }

    /// [`Model::forward_profiled`] inside a workspace: per-layer op
    /// counts with the same zero-allocation execution (one
    /// [`CountingMonitor`] per layer is stack state, not heap). Used by
    /// the sweep harness so a full Table 2 sweep reuses one arena per
    /// experiment model.
    pub fn forward_profiled_in<'w>(
        &self,
        x: &Tensor,
        simd: bool,
        ws: &'w mut Workspace,
    ) -> (&'w Tensor, Vec<LayerProfile>) {
        ws.stage_input(self, x);
        let mut profiles = Vec::with_capacity(self.layers.len());
        let mut cur_is_a = true;
        for (idx, layer) in self.layers.iter().enumerate() {
            let mut mon = CountingMonitor::new();
            ws.run_layer(layer, idx, cur_is_a, simd, &mut mon);
            profiles.push(LayerProfile {
                name: layer.name(),
                counts: mon.counts,
            });
            cur_is_a = !cur_is_a;
        }
        let out = if cur_is_a { &ws.buf_a } else { &ws.buf_b };
        (out, profiles)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::conv::test_random_conv;
    use crate::nn::monitor::NoopMonitor;
    use crate::nn::ops::QuantDense;
    use crate::nn::shift::test_random_shift_conv;
    use crate::nn::{uniform_shifts, AddConv, BnLayer, QuantDepthwise};
    use crate::util::prng::Rng;

    /// A model exercising every layer kind (both shift paths, depthwise,
    /// add-conv + BN, pooling, dense).
    fn kitchen_sink(rng: &mut Rng) -> Model {
        let mut m = Model::new("sink", Shape::new(8, 8, 4), QParam::new(7));
        m.push(Layer::Conv(test_random_conv(rng, 1, 3, 4, 8)));
        m.push(Layer::Relu);
        let mut dww = vec![0i8; 8 * 9];
        rng.fill_i8(&mut dww, -8, 8);
        m.push(Layer::Depthwise(QuantDepthwise {
            kernel: 3,
            channels: 8,
            pad: 1,
            weights: dww,
            bias: vec![0; 8],
            q_in: QParam::new(5),
            q_w: QParam::new(7),
            q_out: QParam::new(5),
        }));
        let mut sc = test_random_shift_conv(rng, 8, 8, 3);
        sc.q_in = QParam::new(5);
        sc.q_out = QParam::new(4);
        sc.shifts = uniform_shifts(8, 3);
        m.push(Layer::Shift(sc));
        let mut acw = vec![0i8; 6 * 9 * 8];
        rng.fill_i8(&mut acw, -16, 16);
        m.push(Layer::AddConv(AddConv {
            kernel: 3,
            in_channels: 8,
            out_channels: 6,
            pad: 1,
            weights: acw,
            bias: vec![0; 6],
            q_in: QParam::new(4),
            q_w: QParam::new(5),
            q_out: QParam::new(3),
        }));
        m.push(Layer::Bn(BnLayer {
            channels: 6,
            m: vec![1 << 5; 6],
            b: vec![7; 6],
            frac_m: 5,
            q_in: QParam::new(3),
            q_out: QParam::new(5),
        }));
        m.push(Layer::MaxPool2);
        m.push(Layer::GlobalAvgPool(Some(QParam::new(6))));
        let mut dw = vec![0i8; 6 * 5];
        rng.fill_i8(&mut dw, -10, 10);
        m.push(Layer::Dense(QuantDense {
            in_features: 6,
            out_features: 5,
            weights: dw,
            bias: vec![0; 5],
            q_in: QParam::new(6),
            q_w: QParam::new(7),
            q_out: QParam::new(5),
        }));
        m
    }

    #[test]
    fn forward_in_bit_exact_with_forward_on_dirty_workspace() {
        let mut rng = Rng::new(0xA11);
        let model = kitchen_sink(&mut rng);
        let mut ws = Workspace::new(&model);
        for simd in [false, true] {
            for trial in 0..4 {
                // fresh random input each trial; the workspace is reused
                // dirty across trials and across path switches
                let mut x = Tensor::zeros(model.input_shape, model.input_q);
                rng.fill_i8(&mut x.data, -64, 63);
                let want = model.forward(&x, simd, &mut NoopMonitor);
                let got = model.forward_in(&x, simd, &mut ws, &mut NoopMonitor);
                assert_eq!(want.shape, got.shape, "simd={simd} trial={trial}");
                assert_eq!(want.q, got.q, "simd={simd} trial={trial}");
                assert_eq!(want.data, got.data, "simd={simd} trial={trial}");
            }
        }
    }

    #[test]
    fn forward_in_event_stream_identical_to_forward() {
        let mut rng = Rng::new(0xB22);
        let model = kitchen_sink(&mut rng);
        let mut ws = Workspace::new(&model);
        let mut x = Tensor::zeros(model.input_shape, model.input_q);
        rng.fill_i8(&mut x.data, -64, 63);
        for simd in [false, true] {
            let mut ma = CountingMonitor::new();
            model.forward(&x, simd, &mut ma);
            let mut mb = CountingMonitor::new();
            model.forward_in(&x, simd, &mut ws, &mut mb);
            assert_eq!(ma.counts, mb.counts, "simd={simd}");
        }
    }

    #[test]
    fn forward_profiled_in_matches_forward_profiled() {
        let mut rng = Rng::new(0xF66);
        let model = kitchen_sink(&mut rng);
        let mut ws = Workspace::new(&model);
        let mut x = Tensor::zeros(model.input_shape, model.input_q);
        rng.fill_i8(&mut x.data, -64, 63);
        for simd in [false, true] {
            let (want_out, want_prof) = model.forward_profiled(&x, simd);
            let (got_out, got_prof) = model.forward_profiled_in(&x, simd, &mut ws);
            assert_eq!(want_out.data, got_out.data, "simd={simd}");
            assert_eq!(want_prof.len(), got_prof.len());
            for (i, (a, b)) in want_prof.iter().zip(&got_prof).enumerate() {
                assert_eq!(a.counts, b.counts, "layer {i} ({}) simd={simd}", a.name);
            }
        }
    }

    #[test]
    fn plan_reports_exact_arena_breakdown() {
        let mut rng = Rng::new(0xC33);
        let model = kitchen_sink(&mut rng);
        let ws = Workspace::new(&model);
        let plan = ws.plan();
        let shapes = model.shapes();
        let max_act = shapes.iter().map(|s| s.len()).max().unwrap();
        assert_eq!(plan.activation_bytes, 2 * max_act);
        let peak_pair = shapes.windows(2).map(|w| w[0].len() + w[1].len()).max().unwrap();
        assert_eq!(plan.peak_pair_bytes, peak_pair);
        // widest column: the 3×3×4 conv (36) vs shift gather (8) vs dense (6)
        assert_eq!(plan.im2col_bytes, 2 * 36 * 2);
        // shift scratch = the shift layer's input map (8×8×8)
        assert_eq!(plan.shift_scratch_bytes, 8 * 8 * 8);
        // widened weights: conv + shift + dense layers, 2 bytes each
        let expect_wq: usize = model
            .layers
            .iter()
            .map(|l| match l {
                Layer::Conv(c) => c.weights.len(),
                Layer::Shift(s) => s.weights.len(),
                Layer::Dense(d) => d.weights.len(),
                _ => 0,
            })
            .sum();
        assert_eq!(plan.widened_weight_bytes, 2 * expect_wq);
        assert_eq!(
            plan.total_bytes(),
            plan.activation_bytes
                + plan.shift_scratch_bytes
                + plan.im2col_bytes
                + plan.widened_weight_bytes
        );
        assert!(plan.summary().contains("arena"));
    }

    #[test]
    fn workspace_capacities_never_grow_after_planning() {
        let mut rng = Rng::new(0xD44);
        let model = kitchen_sink(&mut rng);
        let mut ws = Workspace::new(&model);
        let cap_a = ws.buf_a.data.capacity();
        let cap_b = ws.buf_b.data.capacity();
        let cap_i = ws.shift_inter.data.capacity();
        let mut x = Tensor::zeros(model.input_shape, model.input_q);
        for _ in 0..3 {
            rng.fill_i8(&mut x.data, -64, 63);
            model.forward_in(&x, true, &mut ws, &mut NoopMonitor);
            model.forward_in(&x, false, &mut ws, &mut NoopMonitor);
        }
        assert_eq!(ws.buf_a.data.capacity(), cap_a);
        assert_eq!(ws.buf_b.data.capacity(), cap_b);
        assert_eq!(ws.shift_inter.data.capacity(), cap_i);
    }

    #[test]
    #[should_panic(expected = "workspace was planned for model")]
    fn cross_model_reuse_is_rejected() {
        let mut rng = Rng::new(0xE55);
        let model = kitchen_sink(&mut rng);
        let other = Model::new("other", model.input_shape, model.input_q);
        let mut ws = Workspace::new(&other);
        let x = Tensor::zeros(model.input_shape, model.input_q);
        model.forward_in(&x, false, &mut ws, &mut NoopMonitor);
    }

    #[test]
    #[should_panic(expected = "workspace was planned for model")]
    fn same_shaped_redeployment_with_new_weights_is_rejected() {
        // the stale-arena trap: same name, same layer count, same input
        // shape, different weight values — the cached pre-widened copies
        // would silently be wrong, so the fingerprint must reject it
        let mut rng = Rng::new(0xF77);
        let model = kitchen_sink(&mut rng);
        let mut ws = Workspace::new(&model);
        let mut redeployed = model.clone();
        if let Layer::Conv(c) = &mut redeployed.layers[0] {
            c.weights[0] = c.weights[0].wrapping_add(1);
        }
        let x = Tensor::zeros(redeployed.input_shape, redeployed.input_q);
        redeployed.forward_in(&x, true, &mut ws, &mut NoopMonitor);
    }

    #[test]
    fn fits_accepts_an_identical_clone() {
        let mut rng = Rng::new(0x177);
        let model = kitchen_sink(&mut rng);
        let ws = Workspace::new(&model);
        assert!(ws.fits(&model.clone()));
    }
}
