//! Per-deployment scratch arena for allocation-free inference.
//!
//! A [`Workspace`] holds the mutable state one inference needs, planned
//! once at deploy time so the hot path performs **zero heap
//! allocations** (pinned by `benches/infer_hot.rs` with a counting
//! global allocator) — for the paper-default fixed schedules *and* for
//! arbitrary tuned per-node schedules, on linear chains and residual
//! graphs alike:
//!
//! * activation buffers laid out by the **liveness planner**
//!   ([`crate::nn::arena`]): each graph value's live interval over the
//!   topological order is computed at compile time and values with
//!   disjoint lifetimes share storage. On a linear chain this
//!   degenerates to the classic two-buffer scheme; on residual graphs
//!   the skip operand is kept resident exactly as long as its consumer
//!   needs it. The host engine realizes the plan as one `Tensor` per
//!   lifetime-disjoint *slot* (keeping the kernels' `&Tensor` /
//!   `&mut Tensor` signatures borrow-safe), while [`WorkspacePlan`]
//!   reports the greedy best-fit *packed* arena an MCU deployment
//!   provisions — never larger than the slot total, and on chains never
//!   larger than the legacy 2× largest-activation provisioning (both
//!   property-tested in `nn::plan`);
//! * a flat q15 im2col column arena sized to the widest (P, F)-blocked
//!   candidate of the plan (at the paper's 2-patch design point this is
//!   exactly the CMSIS 2-column cap);
//! * the [`mat_mult_block`](super::blocking::mat_mult_block)
//!   accumulator block of the widest blocked layer;
//! * the shift-convolution intermediate map `I` (Eq. 2) for the scalar
//!   path.
//!
//! The *read-only* state — resolved dispatch, substituted kernel
//! structs, pre-widened q15 weights — lives in the compiled
//! [`ExecPlan`], not here, so the arena is content-free scratch: any
//! plan whose requirements fit the capacities can run in it.
//! [`Workspace::new`] / [`Workspace::new_graph`] additionally store the
//! deployment's two paper-default plans (scalar / SIMD), which is what
//! keeps [`Model::forward_in`] / [`Graph::forward_in`] allocation-free;
//! [`Workspace::for_plan`] sizes a bare arena for one compiled plan (the
//! serving path); a tuned workspace bound to its schedule comes from
//! `TunedSchedule::workspace`.
//!
//! Because every byte is planned up front, the [`WorkspacePlan`] doubles
//! as the deployment's peak-RAM report — the quantity `mcu::footprint`
//! estimates and the paper's §3.3 memory-footprint discussion bounds
//! (and, for tuned plans, an upper bound on the schedule's own
//! `peak_ram_bytes` claim — tested in `nn::plan`).

use crate::quant::QParam;
use crate::util::fnv::Fnv1a;

use super::graph::{Graph, Layer, LayerProfile, Model, NodeOp};
use super::monitor::Monitor;
use super::plan::ExecPlan;
use super::tensor::{Shape, Tensor};

/// Byte-exact breakdown of a planned arena — the deployment's peak-RAM
/// report. All quantities are bytes.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WorkspacePlan {
    /// Liveness-planned activation arena: greedy best-fit offsets over
    /// each value's live interval (TFLite-Micro style), capped by the
    /// lifetime-disjoint slot partition. This is the activation RAM an
    /// MCU deployment provisions.
    pub activation_bytes: usize,
    /// The legacy provisioning figure — two buffers of the largest
    /// activation (the historical ping-pong scheme). Kept so reports can
    /// show the liveness plan's saving; `activation_bytes` ≤ this on
    /// every linear chain.
    pub pingpong_bytes: usize,
    /// Largest concurrently-live (inputs + output) byte sum of any
    /// single step — the liveness lower bound no layout can beat.
    pub peak_pair_bytes: usize,
    /// Shift-convolution intermediate map `I` (scalar path), sized to the
    /// largest shift-layer input.
    pub shift_scratch_bytes: usize,
    /// The q15 im2col / gather / widen column arena of the widest
    /// (P, F)-blocked candidate in the plan.
    pub im2col_bytes: usize,
    /// `mat_mult_block` accumulators of the widest blocked layer.
    pub acc_bytes: usize,
    /// Pre-widened q15 weight copies for the fixed-function SIMD matmul
    /// layers (held by the compiled plan).
    pub widened_weight_bytes: usize,
}

impl WorkspacePlan {
    /// Total arena bytes a deployment provisions at run time (weights in
    /// flash excluded; the widened copies are SRAM on our host-side
    /// engine).
    pub fn total_bytes(&self) -> usize {
        self.activation_bytes
            + self.shift_scratch_bytes
            + self.im2col_bytes
            + self.acc_bytes
            + self.widened_weight_bytes
    }

    /// Field-wise maximum of two plans (the arena a workspace serving
    /// both must provision).
    pub fn max(&self, other: &WorkspacePlan) -> WorkspacePlan {
        WorkspacePlan {
            activation_bytes: self.activation_bytes.max(other.activation_bytes),
            pingpong_bytes: self.pingpong_bytes.max(other.pingpong_bytes),
            peak_pair_bytes: self.peak_pair_bytes.max(other.peak_pair_bytes),
            shift_scratch_bytes: self.shift_scratch_bytes.max(other.shift_scratch_bytes),
            im2col_bytes: self.im2col_bytes.max(other.im2col_bytes),
            acc_bytes: self.acc_bytes.max(other.acc_bytes),
            widened_weight_bytes: self.widened_weight_bytes.max(other.widened_weight_bytes),
        }
    }

    /// One-line report for logs and CLI output: the liveness arena next
    /// to the legacy largest×2 figure, with the per-model delta.
    pub fn summary(&self) -> String {
        let delta = self.pingpong_bytes as i64 - self.activation_bytes as i64;
        format!(
            "arena {} B (liveness activations {} B vs ping-pong {} B [Δ {} B], \
             peak live pair {} B, im2col {} B, block accumulators {} B, \
             shift scratch {} B, widened weights {} B)",
            self.total_bytes(),
            self.activation_bytes,
            self.pingpong_bytes,
            delta,
            self.peak_pair_bytes,
            self.im2col_bytes,
            self.acc_bytes,
            self.shift_scratch_bytes,
            self.widened_weight_bytes
        )
    }
}

/// Reshape a tensor in place without allocating (the target length must
/// be within the capacity planned for it).
#[inline]
pub(crate) fn prepare(t: &mut Tensor, shape: Shape, q: QParam) {
    debug_assert!(
        shape.len() <= t.data.capacity(),
        "workspace buffer capacity {} < required {}",
        t.data.capacity(),
        shape.len()
    );
    t.shape = shape;
    t.q = q;
    t.data.resize(shape.len(), 0);
}

fn tensor_with_capacity(cap: usize, q: QParam) -> Tensor {
    Tensor {
        shape: Shape::new(0, 0, 0),
        q,
        data: Vec::with_capacity(cap),
    }
}

/// Fold one layer's parameter tensors into a fingerprint stream.
fn hash_layer_params(h: &mut Fnv1a, layer: &Layer) {
    match layer {
        Layer::Conv(c) => {
            h.i8s(&c.weights);
            h.i32s(&c.bias);
        }
        Layer::Depthwise(d) => {
            h.i8s(&d.weights);
            h.i32s(&d.bias);
        }
        Layer::Shift(s) => {
            h.i8s(&s.weights);
            h.i32s(&s.bias);
        }
        Layer::AddConv(a) => {
            h.i8s(&a.weights);
            h.i32s(&a.bias);
        }
        Layer::Bn(b) => {
            h.i16s(&b.m);
            h.i32s(&b.b);
        }
        Layer::Dense(d) => {
            h.i8s(&d.weights);
            h.i32s(&d.bias);
        }
        // parameterless layers still advance the stream so layer
        // reordering changes the fingerprint
        Layer::Relu | Layer::MaxPool2 | Layer::GlobalAvgPool(_) => {
            h.byte(0x9e);
        }
    }
}

/// FNV-1a fingerprint of every parameter tensor in the model. Compiled
/// plans (and the workspace's stored default plans) cache substituted
/// kernel structs and pre-widened weight copies, so reusing them against
/// a model whose weights changed (same name, same shapes — e.g. a
/// recalibrated redeployment) would silently compute with stale weights;
/// the fingerprint turns that into a loud failure. Cost: linear in the
/// parameter count, allocation-free — validated at bind time (and on
/// every call in debug builds, which is what the test suite runs).
pub(crate) fn model_weight_fingerprint(model: &Model) -> u64 {
    let mut h = Fnv1a::new();
    for layer in &model.layers {
        hash_layer_params(&mut h, layer);
    }
    h.finish()
}

/// [`model_weight_fingerprint`] for graphs: parameters plus wiring. The
/// linear default (node `i` consuming value `i`) contributes nothing to
/// the stream, so a lowered `Model` fingerprints identically to the
/// model itself; any skip edge, fan-out or residual join perturbs the
/// hash — a workspace planned for a chain cannot be silently reused for
/// a rewired graph with the same ops.
pub(crate) fn graph_weight_fingerprint(graph: &Graph) -> u64 {
    let mut h = Fnv1a::new();
    for (i, node) in graph.nodes.iter().enumerate() {
        match &node.op {
            NodeOp::Layer(layer) => hash_layer_params(&mut h, layer),
            NodeOp::Add(a) => {
                h.byte(0xAD);
                h.i32s(&[a.q_out.frac_bits]);
            }
        }
        if node.inputs.len() != 1 || node.inputs[0] != i {
            h.byte(0x7E);
            for &v in &node.inputs {
                h.i32s(&[v as i32]);
            }
        }
    }
    h.finish()
}

/// The per-deployment scratch arena. Build once per deployed model (per
/// serving worker); reuse across every inference. Deliberately not
/// `Clone`: `Vec::clone` does not preserve spare capacity, which would
/// silently reintroduce steady-state growth — plan a fresh arena per
/// worker instead.
#[derive(Debug)]
pub struct Workspace {
    /// Name, node count, input shape and parameter fingerprint of the
    /// deployment this arena was planned for (guards the `forward_in`
    /// path against cross-model reuse — including a same-shaped
    /// redeployment with different weights, which would otherwise
    /// silently hit the stale compiled default plans).
    model_name: String,
    n_nodes: usize,
    input_shape: Shape,
    weight_fp: u64,
    /// Activation slot buffers: one tensor per lifetime-disjoint slot of
    /// the liveness plan (two for any linear chain).
    pub(crate) slots: Vec<Tensor>,
    /// Shift-conv scalar intermediate map `I`.
    pub(crate) shift_inter: Tensor,
    /// Flat q15 im2col / gather / widen column arena (fixed length =
    /// capacity; kernels slice what they need).
    pub(crate) cols: Vec<i16>,
    /// `mat_mult_block` accumulators of the widest blocked layer.
    pub(crate) acc: Vec<i32>,
    /// The deployment's compiled paper-default plans (scalar / SIMD),
    /// present only on [`Workspace::new`] / [`Workspace::new_graph`]
    /// arenas — what keeps `forward_in` allocation-free without a
    /// per-call compile.
    scalar_plan: Option<Box<ExecPlan>>,
    simd_plan: Option<Box<ExecPlan>>,
    /// A tuned plan bound to this arena (`TunedSchedule::workspace`).
    pub(crate) bound: Option<Box<ExecPlan>>,
    /// Batched-input staging lanes ([`Workspace::for_plan_batch`]):
    /// `max_batch` contiguous copies of the model input, filled by
    /// [`Workspace::stage_batch_input`] and consumed by
    /// [`ExecPlan::run_batch_staged`]. Empty on single-inference arenas.
    pub(crate) batch_in: Vec<i8>,
    /// Batched-output lanes: `max_batch` contiguous copies of the model
    /// output, filled by the batch executors. Empty on single-inference
    /// arenas.
    pub(crate) batch_out: Vec<i8>,
    /// Per-sample staging stride of `batch_in` (the planned input
    /// length).
    batch_in_len: usize,
    /// Per-sample staging stride of `batch_out` (the planned output
    /// length).
    batch_out_len: usize,
    /// Largest batch the staging lanes cover; 0 on single-inference
    /// arenas (the compute arena itself is always per-sample — batching
    /// never widens slots, columns or accumulators).
    max_batch: usize,
    plan: WorkspacePlan,
}

impl Workspace {
    /// Plan and allocate the arena for `model`'s paper-default schedules
    /// (both code paths: the scalar path needs the shift scratch, the
    /// SIMD path the columns, accumulators and widened weights), and
    /// compile those two default plans into the arena so
    /// [`Model::forward_in`] stays allocation-free.
    pub fn new(model: &Model) -> Self {
        let mut ws = Self::new_graph(&Graph::from_model(model));
        // the model lane validates against the model-side fingerprint
        // (identical to the lowered graph's by construction)
        ws.weight_fp = model_weight_fingerprint(model);
        ws
    }

    /// [`Workspace::new`] for a DAG deployment: plan the liveness arena
    /// for `graph` and store its two compiled default plans so
    /// [`Graph::forward_in`] stays allocation-free.
    pub fn new_graph(graph: &Graph) -> Self {
        let scalar = ExecPlan::compile_graph_default(graph, false);
        let simd = ExecPlan::compile_graph_default(graph, true);
        let report = scalar.workspace_plan().max(&simd.workspace_plan());
        let caps: Vec<usize> = scalar
            .slot_caps()
            .iter()
            .zip(simd.slot_caps())
            .map(|(a, b)| *a.max(b))
            .collect();
        let (sc, sacc, ssh) = scalar.scratch_req();
        let (mc, macc, msh) = simd.scratch_req();
        let mut ws = Self::with_capacities(
            &caps,
            sc.max(mc),
            sacc.max(macc),
            ssh.max(msh),
            graph.input_q,
            report,
        );
        ws.model_name = graph.name.clone();
        ws.n_nodes = graph.nodes.len();
        ws.input_shape = graph.input_shape;
        ws.weight_fp = graph_weight_fingerprint(graph);
        ws.scalar_plan = Some(Box::new(scalar));
        ws.simd_plan = Some(Box::new(simd));
        ws
    }

    /// Plan a bare arena sized for one compiled plan — the serving path:
    /// the caller keeps the plan and drives [`ExecPlan::run_in`].
    pub fn for_plan(plan: &ExecPlan) -> Self {
        let (col_len, acc_len, shift_len) = plan.scratch_req();
        let mut ws = Self::with_capacities(
            plan.slot_caps(),
            col_len,
            acc_len,
            shift_len,
            plan.input_q(),
            plan.workspace_plan(),
        );
        ws.model_name = plan.model_name().to_string();
        ws.n_nodes = plan.n_layers();
        ws.input_shape = plan.input_shape();
        ws.weight_fp = plan.weight_fp();
        ws
    }

    /// [`Workspace::for_plan`], additionally binding the plan into the
    /// arena (used by `TunedSchedule::run_in`, which has no other place
    /// to keep the compiled executor without allocating per call).
    pub fn bind(plan: ExecPlan) -> Self {
        let mut ws = Self::for_plan(&plan);
        ws.bound = Some(Box::new(plan));
        ws
    }

    /// [`Workspace::for_plan`] plus batched-I/O staging for up to
    /// `max_batch` samples — the arena [`ExecPlan::run_batch_in`] /
    /// [`ExecPlan::run_batch_staged`] require.
    ///
    /// The *compute* capacities are identical to a single-inference
    /// arena: the batch loop runs one sample at a time through the same
    /// liveness slots, im2col column arena and accumulators, so the
    /// working-set RAM scales only with the widest single sample, never
    /// with the batch. The only addition is the contiguous input/output
    /// staging (`max_batch · input_len` + `max_batch · output_len`
    /// bytes) that lets a serving worker copy request payloads in and
    /// reply logits out without any steady-state allocation.
    pub fn for_plan_batch(plan: &ExecPlan, max_batch: usize) -> Self {
        let mut ws = Self::for_plan(plan);
        ws.max_batch = max_batch.max(1);
        ws.batch_in_len = plan.input_shape().len();
        ws.batch_out_len = plan.output_len();
        ws.batch_in = vec![0i8; ws.max_batch * ws.batch_in_len];
        ws.batch_out = vec![0i8; ws.max_batch * ws.batch_out_len];
        ws
    }

    /// [`Workspace::bind`] with batched-I/O staging
    /// ([`Workspace::for_plan_batch`]) — the arena
    /// `TunedSchedule::run_batch_in` drives.
    pub fn bind_batch(plan: ExecPlan, max_batch: usize) -> Self {
        let mut ws = Self::for_plan_batch(&plan, max_batch);
        ws.bound = Some(Box::new(plan));
        ws
    }

    /// Largest batch the staging lanes cover (0: single-inference arena
    /// without staging — plan one with [`Workspace::for_plan_batch`]).
    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// Per-sample staging strides `(input_len, output_len)` in elements
    /// (both 0 on single-inference arenas).
    pub(crate) fn batch_lane_lens(&self) -> (usize, usize) {
        (self.batch_in_len, self.batch_out_len)
    }

    /// Copy one request payload into staging lane `lane` (allocation
    /// free). Lanes are consumed in order by
    /// [`ExecPlan::run_batch_staged`]; staging a lane ≥ the batch size
    /// actually run is harmless.
    pub fn stage_batch_input(&mut self, lane: usize, input: &[i8]) {
        assert!(
            lane < self.max_batch,
            "staging lane {lane} out of range (arena planned for max_batch {})",
            self.max_batch
        );
        assert_eq!(
            input.len(),
            self.batch_in_len,
            "staged input length mismatch (lane {lane})"
        );
        self.batch_in[lane * self.batch_in_len..(lane + 1) * self.batch_in_len]
            .copy_from_slice(input);
    }

    /// Stage activation slot `slot` for a new sample and fill it from
    /// input staging lane `lane` (split-borrow helper for the batch
    /// executors; the lane stride is the planned input length).
    pub(crate) fn fill_slot_from_lane(&mut self, slot: usize, lane: usize, shape: Shape, q: QParam) {
        let Workspace { slots, batch_in, batch_in_len, .. } = self;
        let t = &mut slots[slot];
        prepare(t, shape, q);
        t.data
            .copy_from_slice(&batch_in[lane * *batch_in_len..(lane + 1) * *batch_in_len]);
    }

    /// Copy activation slot `slot` (holding a finished sample's output)
    /// into output staging lane `lane`.
    pub(crate) fn copy_slot_to_lane(&mut self, slot: usize, lane: usize) {
        let Workspace { slots, batch_out, batch_out_len, .. } = self;
        let d = &slots[slot].data;
        debug_assert_eq!(d.len(), *batch_out_len, "output length drifted from the plan");
        batch_out[lane * *batch_out_len..(lane + 1) * *batch_out_len].copy_from_slice(d);
    }

    fn with_capacities(
        slot_caps: &[usize],
        col_len: usize,
        acc_len: usize,
        shift_len: usize,
        q: QParam,
        plan: WorkspacePlan,
    ) -> Self {
        Self {
            model_name: String::new(),
            n_nodes: 0,
            input_shape: Shape::new(0, 0, 0),
            weight_fp: 0,
            slots: slot_caps.iter().map(|&c| tensor_with_capacity(c, q)).collect(),
            shift_inter: tensor_with_capacity(shift_len, q),
            cols: vec![0i16; col_len],
            acc: vec![0i32; acc_len],
            scalar_plan: None,
            simd_plan: None,
            bound: None,
            batch_in: Vec::new(),
            batch_out: Vec::new(),
            batch_in_len: 0,
            batch_out_len: 0,
            max_batch: 0,
            plan,
        }
    }

    /// The planned arena breakdown (the deployment's peak-RAM report).
    pub fn plan(&self) -> WorkspacePlan {
        self.plan
    }

    /// Whether the arena's capacities cover `plan`'s requirements
    /// (scratch is content-free, so capacity is the only correctness
    /// condition for [`ExecPlan::run_in`]).
    pub fn fits_plan(&self, plan: &ExecPlan) -> bool {
        let (col_len, acc_len, shift_len) = plan.scratch_req();
        plan.slot_caps()
            .iter()
            .enumerate()
            .all(|(s, &cap)| {
                self.slots
                    .get(s)
                    .map(|t| t.data.capacity() >= cap)
                    .unwrap_or(false)
            })
            && self.cols.len() >= col_len
            && self.acc.len() >= acc_len
            && self.shift_inter.data.capacity() >= shift_len
    }

    /// O(1) structural identity: name, node count, input shape.
    fn fits_structurally(&self, name: &str, n_nodes: usize, input_shape: Shape) -> bool {
        self.model_name == name && self.n_nodes == n_nodes && self.input_shape == input_shape
    }

    /// Whether this arena was planned for `model` — structure **and**
    /// parameter values ([`model_weight_fingerprint`], O(params) but
    /// allocation-free). Call this when *binding* a workspace to a model
    /// (the server does at worker spawn); the per-inference path checks
    /// structure every call and re-validates the fingerprint only in
    /// debug builds, so the release hot path pays O(1).
    pub fn fits(&self, model: &Model) -> bool {
        self.fits_structurally(&model.name, model.layers.len(), model.input_shape)
            && self.weight_fp == model_weight_fingerprint(model)
    }

    /// [`Workspace::fits`] for graph deployments (parameters + wiring).
    pub fn fits_graph(&self, graph: &Graph) -> bool {
        self.fits_structurally(&graph.name, graph.nodes.len(), graph.input_shape)
            && self.weight_fp == graph_weight_fingerprint(graph)
    }

    /// The slot holding the last run's output.
    pub(crate) fn output(&self, slot: usize) -> &Tensor {
        &self.slots[slot]
    }

    /// Guard the `forward_in` family: the stored default plans were
    /// compiled from the deployment this arena was planned for; running
    /// a different (or redeployed) model through them would silently use
    /// stale weights. Structural identity is asserted on every call; the
    /// full parameter fingerprint is re-asserted in debug builds —
    /// release callers validate at bind time via [`Workspace::fits`] /
    /// [`Workspace::fits_graph`].
    fn check_model(&self, model: &Model) {
        let ok = if cfg!(debug_assertions) {
            self.fits(model)
        } else {
            self.fits_structurally(&model.name, model.layers.len(), model.input_shape)
        };
        assert!(
            ok,
            "workspace was planned for model {:?}, not {:?} (stale parameters?)",
            self.model_name, model.name
        );
    }

    fn check_graph(&self, graph: &Graph) {
        let ok = if cfg!(debug_assertions) {
            self.fits_graph(graph)
        } else {
            self.fits_structurally(&graph.name, graph.nodes.len(), graph.input_shape)
        };
        assert!(
            ok,
            "workspace was planned for model {:?}, not {:?} (stale parameters or rewired graph?)",
            self.model_name, graph.name
        );
    }

    /// Take one of the stored default plans out for a run (no
    /// allocation; put back via [`Workspace::put_default_plan`]).
    fn take_default_plan(&mut self, simd: bool) -> Box<ExecPlan> {
        let slot = if simd { &mut self.simd_plan } else { &mut self.scalar_plan };
        slot.take().expect(
            "workspace holds no default plans (built with Workspace::for_plan?) — \
             drive ExecPlan::run_in directly",
        )
    }

    fn put_default_plan(&mut self, simd: bool, plan: Box<ExecPlan>) {
        let slot = if simd { &mut self.simd_plan } else { &mut self.scalar_plan };
        *slot = Some(plan);
    }
}

impl Model {
    /// Run an inference inside a pre-planned [`Workspace`]: bit-exact
    /// with [`Model::forward`], identical micro-op event stream, zero
    /// heap allocations in steady state. A thin wrapper over the
    /// workspace's compiled default [`ExecPlan`]. The returned reference
    /// points into the workspace's output buffer and is valid until the
    /// next run.
    pub fn forward_in<'w, M: Monitor>(
        &self,
        x: &Tensor,
        simd: bool,
        ws: &'w mut Workspace,
        mon: &mut M,
    ) -> &'w Tensor {
        ws.check_model(self);
        let plan = ws.take_default_plan(simd);
        let out_slot = plan.run_steps(x, ws, mon);
        ws.put_default_plan(simd, plan);
        ws.output(out_slot)
    }

    /// [`Model::forward_profiled`] inside a workspace: per-layer op
    /// counts with the same zero-allocation execution (one
    /// `CountingMonitor` per layer is stack state, not heap). Used by
    /// the sweep harness so a full Table 2 sweep reuses one arena per
    /// experiment model.
    pub fn forward_profiled_in<'w>(
        &self,
        x: &Tensor,
        simd: bool,
        ws: &'w mut Workspace,
    ) -> (&'w Tensor, Vec<LayerProfile>) {
        ws.check_model(self);
        let plan = ws.take_default_plan(simd);
        // run_profiled_in borrows ws for the output reference; go through
        // the step loop manually to keep the take/put dance borrow-clean
        let (out_slot, profiles) = plan.run_steps_profiled(x, ws);
        ws.put_default_plan(simd, plan);
        (ws.output(out_slot), profiles)
    }
}

impl Graph {
    /// [`Model::forward_in`] for DAG deployments: run inside a
    /// [`Workspace::new_graph`] arena — bit-exact with
    /// [`Graph::forward`], identical event stream, zero steady-state
    /// heap allocations.
    pub fn forward_in<'w, M: Monitor>(
        &self,
        x: &Tensor,
        simd: bool,
        ws: &'w mut Workspace,
        mon: &mut M,
    ) -> &'w Tensor {
        ws.check_graph(self);
        let plan = ws.take_default_plan(simd);
        let out_slot = plan.run_steps(x, ws, mon);
        ws.put_default_plan(simd, plan);
        ws.output(out_slot)
    }

    /// [`Model::forward_profiled_in`] for DAG deployments.
    pub fn forward_profiled_in<'w>(
        &self,
        x: &Tensor,
        simd: bool,
        ws: &'w mut Workspace,
    ) -> (&'w Tensor, Vec<LayerProfile>) {
        ws.check_graph(self);
        let plan = ws.take_default_plan(simd);
        let (out_slot, profiles) = plan.run_steps_profiled(x, ws);
        ws.put_default_plan(simd, plan);
        (ws.output(out_slot), profiles)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::conv::test_random_conv;
    use crate::nn::monitor::{CountingMonitor, NoopMonitor};
    use crate::nn::ops::QuantDense;
    use crate::nn::shift::test_random_shift_conv;
    use crate::nn::{uniform_shifts, AddConv, BnLayer, QuantDepthwise};
    use crate::util::prng::Rng;

    /// A model exercising every layer kind (both shift paths, depthwise,
    /// add-conv + BN, pooling, dense).
    fn kitchen_sink(rng: &mut Rng) -> Model {
        let mut m = Model::new("sink", Shape::new(8, 8, 4), QParam::new(7));
        m.push(Layer::Conv(test_random_conv(rng, 1, 3, 4, 8)));
        m.push(Layer::Relu);
        let mut dww = vec![0i8; 8 * 9];
        rng.fill_i8(&mut dww, -8, 8);
        m.push(Layer::Depthwise(QuantDepthwise {
            kernel: 3,
            channels: 8,
            pad: 1,
            weights: dww,
            bias: vec![0; 8],
            q_in: QParam::new(5),
            q_w: QParam::new(7),
            q_out: QParam::new(5),
        }));
        let mut sc = test_random_shift_conv(rng, 8, 8, 3);
        sc.q_in = QParam::new(5);
        sc.q_out = QParam::new(4);
        sc.shifts = uniform_shifts(8, 3);
        m.push(Layer::Shift(sc));
        let mut acw = vec![0i8; 6 * 9 * 8];
        rng.fill_i8(&mut acw, -16, 16);
        m.push(Layer::AddConv(AddConv {
            kernel: 3,
            in_channels: 8,
            out_channels: 6,
            pad: 1,
            weights: acw,
            bias: vec![0; 6],
            q_in: QParam::new(4),
            q_w: QParam::new(5),
            q_out: QParam::new(3),
        }));
        m.push(Layer::Bn(BnLayer {
            channels: 6,
            m: vec![1 << 5; 6],
            b: vec![7; 6],
            frac_m: 5,
            q_in: QParam::new(3),
            q_out: QParam::new(5),
        }));
        m.push(Layer::MaxPool2);
        m.push(Layer::GlobalAvgPool(Some(QParam::new(6))));
        let mut dw = vec![0i8; 6 * 5];
        rng.fill_i8(&mut dw, -10, 10);
        m.push(Layer::Dense(QuantDense {
            in_features: 6,
            out_features: 5,
            weights: dw,
            bias: vec![0; 5],
            q_in: QParam::new(6),
            q_w: QParam::new(7),
            q_out: QParam::new(5),
        }));
        m
    }

    #[test]
    fn forward_in_bit_exact_with_forward_on_dirty_workspace() {
        let mut rng = Rng::new(0xA11);
        let model = kitchen_sink(&mut rng);
        let mut ws = Workspace::new(&model);
        for simd in [false, true] {
            for trial in 0..4 {
                // fresh random input each trial; the workspace is reused
                // dirty across trials and across path switches
                let mut x = Tensor::zeros(model.input_shape, model.input_q);
                rng.fill_i8(&mut x.data, -64, 63);
                let want = model.forward(&x, simd, &mut NoopMonitor);
                let got = model.forward_in(&x, simd, &mut ws, &mut NoopMonitor);
                assert_eq!(want.shape, got.shape, "simd={simd} trial={trial}");
                assert_eq!(want.q, got.q, "simd={simd} trial={trial}");
                assert_eq!(want.data, got.data, "simd={simd} trial={trial}");
            }
        }
    }

    #[test]
    fn forward_in_event_stream_identical_to_forward() {
        let mut rng = Rng::new(0xB22);
        let model = kitchen_sink(&mut rng);
        let mut ws = Workspace::new(&model);
        let mut x = Tensor::zeros(model.input_shape, model.input_q);
        rng.fill_i8(&mut x.data, -64, 63);
        for simd in [false, true] {
            let mut ma = CountingMonitor::new();
            model.forward(&x, simd, &mut ma);
            let mut mb = CountingMonitor::new();
            model.forward_in(&x, simd, &mut ws, &mut mb);
            assert_eq!(ma.counts, mb.counts, "simd={simd}");
        }
    }

    #[test]
    fn forward_profiled_in_matches_forward_profiled() {
        let mut rng = Rng::new(0xF66);
        let model = kitchen_sink(&mut rng);
        let mut ws = Workspace::new(&model);
        let mut x = Tensor::zeros(model.input_shape, model.input_q);
        rng.fill_i8(&mut x.data, -64, 63);
        for simd in [false, true] {
            let (want_out, want_prof) = model.forward_profiled(&x, simd);
            let (got_out, got_prof) = model.forward_profiled_in(&x, simd, &mut ws);
            assert_eq!(want_out.data, got_out.data, "simd={simd}");
            assert_eq!(want_prof.len(), got_prof.len());
            for (i, (a, b)) in want_prof.iter().zip(&got_prof).enumerate() {
                assert_eq!(a.counts, b.counts, "layer {i} ({}) simd={simd}", a.name);
            }
        }
    }

    #[test]
    fn plan_reports_liveness_arena_breakdown() {
        let mut rng = Rng::new(0xC33);
        let model = kitchen_sink(&mut rng);
        let ws = Workspace::new(&model);
        let plan = ws.plan();
        let shapes = model.shapes();
        let max_act = shapes.iter().map(|s| s.len()).max().unwrap();
        // the legacy figure is still reported for the delta
        assert_eq!(plan.pingpong_bytes, 2 * max_act);
        let peak_pair = shapes.windows(2).map(|w| w[0].len() + w[1].len()).max().unwrap();
        assert_eq!(plan.peak_pair_bytes, peak_pair);
        // liveness packing on a chain: bounded below by the largest live
        // pair and above by the ping-pong provisioning
        assert!(plan.activation_bytes >= peak_pair);
        assert!(plan.activation_bytes <= plan.pingpong_bytes);
        // widest column arena: the 3×3×4 conv blocked at the 2-patch
        // design point (2 × 36 q15 values) vs shift gather (2 × 8) vs
        // dense widening (6)
        assert_eq!(plan.im2col_bytes, 2 * 36 * 2);
        // block accumulators: the 2×2 design point
        assert_eq!(plan.acc_bytes, 4 * 4);
        // shift scratch = the shift layer's input map (8×8×8)
        assert_eq!(plan.shift_scratch_bytes, 8 * 8 * 8);
        // widened weights: the blocked conv matmul consumes q7 rows
        // directly, so only the fixed-function shift and dense SIMD
        // kernels hold q15 copies
        let expect_wq: usize = model
            .layers
            .iter()
            .map(|l| match l {
                Layer::Shift(s) => s.weights.len(),
                Layer::Dense(d) => d.weights.len(),
                _ => 0,
            })
            .sum();
        assert_eq!(plan.widened_weight_bytes, 2 * expect_wq);
        assert_eq!(
            plan.total_bytes(),
            plan.activation_bytes
                + plan.shift_scratch_bytes
                + plan.im2col_bytes
                + plan.acc_bytes
                + plan.widened_weight_bytes
        );
        assert!(plan.summary().contains("arena"));
        assert!(plan.summary().contains("ping-pong"));
    }

    #[test]
    fn chain_workspaces_keep_exactly_two_slots() {
        // linear chains must not regress past the historical two-buffer
        // scheme: the liveness slot partition degenerates to ping-pong
        let mut rng = Rng::new(0x2C4);
        let model = kitchen_sink(&mut rng);
        let ws = Workspace::new(&model);
        assert_eq!(ws.slots.len(), 2);
        let max_act = model.shapes().iter().map(|s| s.len()).max().unwrap();
        assert!(ws.slots.iter().all(|t| t.data.capacity() <= max_act));
    }

    #[test]
    fn workspace_capacities_never_grow_after_planning() {
        let mut rng = Rng::new(0xD44);
        let model = kitchen_sink(&mut rng);
        let mut ws = Workspace::new(&model);
        let caps: Vec<usize> = ws.slots.iter().map(|t| t.data.capacity()).collect();
        let cap_i = ws.shift_inter.data.capacity();
        let cap_c = ws.cols.len();
        let cap_k = ws.acc.len();
        let mut x = Tensor::zeros(model.input_shape, model.input_q);
        for _ in 0..3 {
            rng.fill_i8(&mut x.data, -64, 63);
            model.forward_in(&x, true, &mut ws, &mut NoopMonitor);
            model.forward_in(&x, false, &mut ws, &mut NoopMonitor);
        }
        let caps_after: Vec<usize> = ws.slots.iter().map(|t| t.data.capacity()).collect();
        assert_eq!(caps, caps_after);
        assert_eq!(ws.shift_inter.data.capacity(), cap_i);
        assert_eq!(ws.cols.len(), cap_c);
        assert_eq!(ws.acc.len(), cap_k);
    }

    #[test]
    #[should_panic(expected = "workspace was planned for model")]
    fn cross_model_reuse_is_rejected() {
        let mut rng = Rng::new(0xE55);
        let model = kitchen_sink(&mut rng);
        let other = Model::new("other", model.input_shape, model.input_q);
        let mut ws = Workspace::new(&other);
        let x = Tensor::zeros(model.input_shape, model.input_q);
        model.forward_in(&x, false, &mut ws, &mut NoopMonitor);
    }

    #[test]
    #[should_panic(expected = "workspace was planned for model")]
    fn same_shaped_redeployment_with_new_weights_is_rejected() {
        // the stale-arena trap: same name, same layer count, same input
        // shape, different weight values — the workspace's compiled
        // default plans would silently execute the old weights, so the
        // fingerprint must reject it
        let mut rng = Rng::new(0xF77);
        let model = kitchen_sink(&mut rng);
        let mut ws = Workspace::new(&model);
        let mut redeployed = model.clone();
        if let Layer::Conv(c) = &mut redeployed.layers[0] {
            c.weights[0] = c.weights[0].wrapping_add(1);
        }
        let x = Tensor::zeros(redeployed.input_shape, redeployed.input_q);
        redeployed.forward_in(&x, true, &mut ws, &mut NoopMonitor);
    }

    #[test]
    #[should_panic(expected = "workspace was planned for model")]
    fn rewired_graph_with_same_ops_is_rejected() {
        // same ops, same shapes — but a skip edge: the wiring enters the
        // graph fingerprint, so the chain-planned workspace must refuse
        let mut rng = Rng::new(0x3AA);
        let mut conv = test_random_conv(&mut rng, 1, 3, 4, 4);
        conv.q_in = QParam::new(5);
        conv.q_out = QParam::new(5);
        let mut chain = Graph::new("wired", Shape::new(6, 6, 4), QParam::new(5));
        let v = chain.layer(chain.input(), Layer::Conv(conv.clone()));
        let v = chain.layer(v, Layer::Relu);
        chain.layer(v, Layer::Relu); // consumes the previous value
        let mut fanout = Graph::new("wired", Shape::new(6, 6, 4), QParam::new(5));
        let s0 = fanout.input();
        let v = fanout.layer(s0, Layer::Conv(conv));
        let _ = fanout.layer(v, Layer::Relu);
        fanout.layer(v, Layer::Relu); // skip edge: consumes the conv output
        let mut ws = Workspace::new_graph(&chain);
        let x = Tensor::zeros(fanout.input_shape, fanout.input_q);
        fanout.forward_in(&x, false, &mut ws, &mut NoopMonitor);
    }

    #[test]
    fn graph_fingerprint_matches_model_fingerprint_on_chains() {
        let mut rng = Rng::new(0x4BB);
        let model = kitchen_sink(&mut rng);
        let graph = Graph::from_model(&model);
        assert_eq!(model_weight_fingerprint(&model), graph_weight_fingerprint(&graph));
    }

    #[test]
    fn graph_forward_in_matches_graph_forward_dirty() {
        // residual graph through the stored default plans: bit-exact and
        // event-identical on a dirty arena, both code paths
        let mut rng = Rng::new(0x5CC);
        let mut g = Graph::new("res-ws", Shape::new(6, 6, 4), QParam::new(5));
        let skip = g.input();
        let mut conv = test_random_conv(&mut rng, 1, 3, 4, 4);
        conv.q_in = QParam::new(5);
        conv.q_out = QParam::new(5);
        let v = g.layer(skip, Layer::Conv(conv));
        let v = g.layer(v, Layer::Relu);
        g.add(skip, v, QParam::new(4));
        let mut ws = Workspace::new_graph(&g);
        assert!(ws.fits_graph(&g));
        for simd in [false, true] {
            for trial in 0..3 {
                let mut x = Tensor::zeros(g.input_shape, g.input_q);
                rng.fill_i8(&mut x.data, -64, 63);
                let mut ma = CountingMonitor::new();
                let want = g.forward(&x, simd, &mut ma);
                let mut mb = CountingMonitor::new();
                let got = g.forward_in(&x, simd, &mut ws, &mut mb);
                assert_eq!(want.data, got.data, "simd={simd} trial={trial}");
                assert_eq!(ma.counts, mb.counts, "simd={simd} trial={trial}");
            }
        }
    }

    #[test]
    fn fits_accepts_an_identical_clone() {
        let mut rng = Rng::new(0x177);
        let model = kitchen_sink(&mut rng);
        let ws = Workspace::new(&model);
        assert!(ws.fits(&model.clone()));
    }

    #[test]
    #[should_panic(expected = "holds no default plans")]
    fn bare_plan_arena_rejects_forward_in() {
        let mut rng = Rng::new(0x288);
        let model = kitchen_sink(&mut rng);
        let plan = ExecPlan::compile_default(&model, true);
        let mut ws = Workspace::for_plan(&plan);
        let x = Tensor::zeros(model.input_shape, model.input_q);
        model.forward_in(&x, true, &mut ws, &mut NoopMonitor);
    }
}
