//! Analytic-vs-measured drift monitor: the paper's headline claim — a
//! *linear relationship between theoretical complexity and measured
//! latency* (§4.1, regression scores ≥0.95) — turned into a runtime
//! invariant. Every sampled batch contributes per-node measured host
//! wall times; each node also has an analytic prediction (its
//! [`crate::nn::OpCounts`] pushed through the [`crate::mcu`] cycle
//! model). If the paper's linearity holds on the host too, measured
//! nanoseconds should be an affine function of predicted cycles across
//! all nodes of all models; [`DriftMonitor::report`] fits that line
//! with [`crate::util::stats::linreg`] and flags nodes that depart from
//! it by more than a configurable relative tolerance — the calibration
//! signal the ROADMAP's host-SIMD backend comparison needs.
//!
//! [`NodeCost`] is the one serializer for per-node cost records: the
//! offline `convbench profile --json` view and the runtime drift report
//! emit the same fields, so the two are diffable directly.

use std::collections::BTreeMap;

use crate::mcu::{measure, McuConfig, Measurement, PathClass};
use crate::nn::{counts, ExecPlan, Graph, NodeOp};
use crate::tuner::space::{self, Candidate};
use crate::util::json::Json;
use crate::util::stats::{linreg, LinearFit};

/// Per-node cost record: analytic prediction plus memory footprint.
/// Shared between `convbench profile --json` and the drift monitor so
/// offline and runtime views are field-compatible.
#[derive(Clone, Debug, PartialEq)]
pub struct NodeCost {
    /// Kernel name (plan step name).
    pub node: String,
    /// Step index in the plan / node index in the graph.
    pub index: usize,
    /// Predicted cycles on the modeled MCU.
    pub cycles: f64,
    /// Predicted latency at the configured clock, µs.
    pub latency_us: f64,
    /// Predicted energy, µJ.
    pub energy_uj: f64,
    /// Memory-access events (the paper's Fig. 3 quantity).
    pub mem_accesses: u64,
    /// Effective multiply-accumulates (`__SMLAD` counts double).
    pub effective_macs: u64,
    /// Activation arena bytes live while this node runs.
    pub arena_bytes: usize,
    /// Host execution backend the node's kernel deploys with
    /// ([`crate::nn::Backend::as_str`] spelling). The analytic costs are
    /// backend-invariant (modeled MCU stream); measured host wall time
    /// is not, so the drift monitor fits ns-per-cycle per backend.
    pub backend: String,
}

impl NodeCost {
    /// Build from a measurement (shared by the profile CLI and
    /// [`plan_node_costs`]).
    pub fn from_measurement(
        node: &str,
        index: usize,
        m: &Measurement,
        arena_bytes: usize,
        backend: &str,
    ) -> Self {
        Self {
            node: node.to_string(),
            index,
            cycles: m.cycles,
            latency_us: m.latency_s * 1e6,
            energy_uj: m.energy_mj * 1e3,
            mem_accesses: m.mem_accesses,
            effective_macs: m.effective_macs,
            arena_bytes,
            backend: backend.to_string(),
        }
    }

    /// The shared per-node JSON serialization.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .field("node", self.node.as_str())
            .field("index", self.index)
            .field("cycles", self.cycles)
            .field("latency_us", self.latency_us)
            .field("energy_uj", self.energy_uj)
            .field("mem_accesses", self.mem_accesses)
            .field("effective_macs", self.effective_macs)
            .field("arena_bytes", self.arena_bytes)
            .field("backend", self.backend.as_str())
    }
}

/// Analytic per-node costs for a compiled plan: each node's op counts
/// under its scheduled candidate (`counts × McuConfig`), in plan step
/// order. `schedule` must align with the graph's nodes (e.g.
/// [`ExecPlan::candidates`]).
pub fn plan_node_costs(
    graph: &Graph,
    schedule: &[Candidate],
    plan: &ExecPlan,
    cfg: &McuConfig,
) -> Vec<NodeCost> {
    let shapes = graph.value_shapes();
    graph
        .nodes
        .iter()
        .zip(schedule)
        .enumerate()
        .map(|(i, (node, cand))| {
            let in_shape = &shapes[node.inputs[0]];
            let (op_counts, path) = match &node.op {
                NodeOp::Layer(l) => {
                    (space::analytic_counts(l, cand, in_shape), cand.lowering.path_class())
                }
                NodeOp::Add(_) => (counts::residual_add_counts(in_shape), PathClass::Scalar),
            };
            let m = measure(&op_counts, path, cfg);
            NodeCost::from_measurement(
                node.op.name(),
                i,
                &m,
                plan.layer_ram_bytes(i),
                cand.backend.as_str(),
            )
        })
        .collect()
}

/// Rolling measured-time accumulator for one node.
#[derive(Clone, Debug)]
struct NodeAccum {
    cost: NodeCost,
    measured_ns_sum: f64,
    samples: u64,
}

/// One node's row in a [`DriftReport`].
#[derive(Clone, Debug)]
pub struct DriftRecord {
    /// Owning model name.
    pub model: String,
    /// Analytic side (the shared [`NodeCost`] record).
    pub cost: NodeCost,
    /// Mean measured host wall time, ns.
    pub mean_measured_ns: f64,
    /// Measured batches contributing to the mean.
    pub samples: u64,
    /// Rolling ratio: mean measured ns ÷ predicted cycles.
    pub ns_per_cycle: f64,
    /// True when this node departs from the model-wide fit by more
    /// than the report's tolerance.
    pub flagged: bool,
}

/// Snapshot of the drift state: the model-wide linear fit of measured
/// ns against predicted cycles, plus every measured node's record.
#[derive(Clone, Debug)]
pub struct DriftReport {
    /// Relative tolerance used for flagging.
    pub tolerance: f64,
    /// OLS fit of measured ns vs predicted cycles across all measured
    /// nodes (`None` below 2 points or under degenerate variance).
    pub fit: Option<LinearFit>,
    /// The same fit restricted to each executing backend (keyed by
    /// [`crate::nn::Backend::as_str`] spelling, in key order). The
    /// predicted cycles are backend-invariant, so a vec kernel's lower
    /// host wall time shows up as a distinct (smaller) ns-per-cycle
    /// slope here rather than as drift noise in the global fit.
    pub backend_fits: Vec<(String, LinearFit)>,
    /// Per-node records, in (model, node index) order.
    pub records: Vec<DriftRecord>,
}

impl DriftReport {
    /// Number of flagged nodes.
    pub fn flagged(&self) -> usize {
        self.records.iter().filter(|r| r.flagged).count()
    }

    /// True when every measured node's ns-per-cycle ratio is finite —
    /// the acceptance invariant benches assert over the model zoo.
    pub fn all_ratios_finite(&self) -> bool {
        self.records.iter().all(|r| r.ns_per_cycle.is_finite())
    }

    /// JSON form: the fit, per-node records (each embedding the shared
    /// [`NodeCost::to_json`] fields), and the flag count.
    pub fn to_json(&self) -> Json {
        let fit = match &self.fit {
            Some(f) => Json::obj()
                .field("ns_per_cycle", f.a)
                .field("intercept_ns", f.b)
                .field("r2", f.r2)
                .field("n", f.n),
            None => Json::Null,
        };
        let backend_fits = Json::Obj(
            self.backend_fits
                .iter()
                .map(|(backend, f)| {
                    (
                        backend.clone(),
                        Json::obj()
                            .field("ns_per_cycle", f.a)
                            .field("intercept_ns", f.b)
                            .field("r2", f.r2)
                            .field("n", f.n),
                    )
                })
                .collect(),
        );
        let nodes: Vec<Json> = self
            .records
            .iter()
            .map(|r| {
                r.cost
                    .to_json()
                    .field("model", r.model.as_str())
                    .field("mean_measured_ns", r.mean_measured_ns)
                    .field("samples", r.samples)
                    .field("ns_per_cycle", r.ns_per_cycle)
                    .field("flagged", r.flagged)
            })
            .collect();
        Json::obj()
            .field("tolerance", self.tolerance)
            .field("fit", fit)
            .field("backend_fits", backend_fits)
            .field("nodes", Json::Arr(nodes))
            .field("flagged", self.flagged())
    }
}

/// Accumulates per-(model, node) measured wall times against registered
/// analytic costs. The server holds one behind a mutex touched only on
/// sampled batches; benches drive it directly.
#[derive(Debug, Default)]
pub struct DriftMonitor {
    models: BTreeMap<String, Vec<NodeAccum>>,
}

impl DriftMonitor {
    /// Empty monitor; call [`DriftMonitor::register`] per model.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a model's analytic node costs (replaces any previous
    /// registration and its accumulated measurements).
    pub fn register(&mut self, model: &str, costs: Vec<NodeCost>) {
        let accums = costs
            .into_iter()
            .map(|cost| NodeAccum {
                cost,
                measured_ns_sum: 0.0,
                samples: 0,
            })
            .collect();
        self.models.insert(model.to_string(), accums);
    }

    /// Record one measured execution of `node_index` (plan step) of
    /// `model`. Unregistered models/nodes are ignored.
    pub fn record(&mut self, model: &str, node_index: usize, measured_ns: f64) {
        if let Some(accums) = self.models.get_mut(model) {
            if let Some(a) = accums.get_mut(node_index) {
                a.measured_ns_sum += measured_ns;
                a.samples += 1;
            }
        }
    }

    /// Fit measured ns against predicted cycles across every measured
    /// node and flag nodes whose mean departs from the fit by more than
    /// `tolerance` (relative to the fitted value).
    pub fn report(&self, tolerance: f64) -> DriftReport {
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        let mut by_backend: BTreeMap<&str, (Vec<f64>, Vec<f64>)> = BTreeMap::new();
        for accums in self.models.values() {
            for a in accums {
                if a.samples > 0 {
                    let mean_ns = a.measured_ns_sum / a.samples as f64;
                    xs.push(a.cost.cycles);
                    ys.push(mean_ns);
                    let (bx, by) = by_backend.entry(a.cost.backend.as_str()).or_default();
                    bx.push(a.cost.cycles);
                    by.push(mean_ns);
                }
            }
        }
        let fit = linreg(&xs, &ys);
        let backend_fits: Vec<(String, LinearFit)> = by_backend
            .into_iter()
            .filter_map(|(backend, (bx, by))| {
                linreg(&bx, &by).map(|f| (backend.to_string(), f))
            })
            .collect();
        let mut records = Vec::new();
        for (model, accums) in &self.models {
            for a in accums {
                if a.samples == 0 {
                    continue;
                }
                let mean_ns = a.measured_ns_sum / a.samples as f64;
                let flagged = match &fit {
                    Some(f) => {
                        let expected = f.a * a.cost.cycles + f.b;
                        (mean_ns - expected).abs() > tolerance * expected.abs().max(f64::EPSILON)
                    }
                    None => false,
                };
                records.push(DriftRecord {
                    model: model.clone(),
                    cost: a.cost.clone(),
                    mean_measured_ns: mean_ns,
                    samples: a.samples,
                    ns_per_cycle: mean_ns / a.cost.cycles,
                    flagged,
                });
            }
        }
        DriftReport {
            tolerance,
            fit,
            backend_fits,
            records,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cost(name: &str, index: usize, cycles: f64) -> NodeCost {
        cost_on(name, index, cycles, "scalar")
    }

    fn cost_on(name: &str, index: usize, cycles: f64, backend: &str) -> NodeCost {
        NodeCost {
            node: name.to_string(),
            index,
            cycles,
            latency_us: cycles / 84.0,
            energy_uj: cycles * 0.5e-3,
            mem_accesses: cycles as u64 / 2,
            effective_macs: cycles as u64 / 4,
            arena_bytes: 1024,
            backend: backend.to_string(),
        }
    }

    #[test]
    fn linear_measurements_fit_with_no_flags() {
        let mut mon = DriftMonitor::new();
        mon.register(
            "m",
            vec![cost("conv", 0, 1000.0), cost("relu", 1, 100.0), cost("dense", 2, 5000.0)],
        );
        // measured = 12 ns/cycle exactly → perfect fit, nothing flagged
        for _ in 0..3 {
            mon.record("m", 0, 12_000.0);
            mon.record("m", 1, 1_200.0);
            mon.record("m", 2, 60_000.0);
        }
        let rep = mon.report(0.25);
        let fit = rep.fit.expect("fit over 3 nodes");
        assert!((fit.a - 12.0).abs() < 1e-6, "slope {}", fit.a);
        assert!(fit.r2 > 0.999);
        assert_eq!(rep.flagged(), 0);
        assert!(rep.all_ratios_finite());
        assert_eq!(rep.records.len(), 3);
        assert!((rep.records[0].ns_per_cycle - 12.0).abs() < 1e-9);
        assert_eq!(rep.records[0].samples, 3);
    }

    #[test]
    fn outlier_node_is_flagged() {
        let mut mon = DriftMonitor::new();
        mon.register(
            "m",
            vec![
                cost("a", 0, 1000.0),
                cost("b", 1, 2000.0),
                cost("c", 2, 3000.0),
                cost("d", 3, 4000.0),
            ],
        );
        mon.record("m", 0, 10_000.0);
        mon.record("m", 1, 20_000.0);
        mon.record("m", 2, 90_000.0); // 3× the trend
        mon.record("m", 3, 40_000.0);
        let rep = mon.report(0.25);
        let c = rep.records.iter().find(|r| r.cost.node == "c").unwrap();
        assert!(c.flagged, "outlier must be flagged");
        let a = rep.records.iter().find(|r| r.cost.node == "a").unwrap();
        assert!(!a.flagged, "on-trend node must not be flagged");
    }

    #[test]
    fn unmeasured_and_unknown_nodes_are_ignored() {
        let mut mon = DriftMonitor::new();
        mon.register("m", vec![cost("a", 0, 1000.0), cost("b", 1, 2000.0)]);
        mon.record("m", 0, 5_000.0);
        mon.record("m", 99, 5_000.0); // out of range: ignored
        mon.record("ghost", 0, 5_000.0); // unregistered: ignored
        let rep = mon.report(0.5);
        assert_eq!(rep.records.len(), 1, "only the measured node reports");
        assert!(rep.fit.is_none(), "one point cannot fit a line");
        assert_eq!(rep.flagged(), 0);
    }

    #[test]
    fn backend_fits_separate_host_speeds() {
        let mut mon = DriftMonitor::new();
        mon.register(
            "m",
            vec![
                cost_on("conv", 0, 1000.0, "scalar"),
                cost_on("dense", 1, 4000.0, "scalar"),
                cost_on("conv.vec", 2, 1000.0, "vec"),
                cost_on("dense.vec", 3, 4000.0, "vec"),
            ],
        );
        // identical modeled cycles; the vec kernels run 3× faster on the
        // host (4 vs 12 ns/cycle) — a backend property, not drift
        mon.record("m", 0, 12_000.0);
        mon.record("m", 1, 48_000.0);
        mon.record("m", 2, 4_000.0);
        mon.record("m", 3, 16_000.0);
        let rep = mon.report(10.0);
        assert_eq!(rep.backend_fits.len(), 2);
        let fits: BTreeMap<&str, &LinearFit> =
            rep.backend_fits.iter().map(|(b, f)| (b.as_str(), f)).collect();
        assert!((fits["scalar"].a - 12.0).abs() < 1e-6, "scalar slope {}", fits["scalar"].a);
        assert!((fits["vec"].a - 4.0).abs() < 1e-6, "vec slope {}", fits["vec"].a);
        assert!(fits["vec"].a < fits["scalar"].a, "vec must fit a smaller ns-per-cycle");
        let j = Json::parse(&rep.to_json().to_string()).expect("valid json");
        let bf = j.get("backend_fits").unwrap();
        assert!(bf.get("scalar").is_some() && bf.get("vec").is_some());
        for n in j.get("nodes").and_then(|v| v.as_arr()).unwrap() {
            assert!(n.get("backend").is_some(), "records carry the executing backend");
        }
    }

    #[test]
    fn report_serializes_and_parses_back() {
        let mut mon = DriftMonitor::new();
        mon.register("m", vec![cost("a", 0, 1000.0), cost("b", 1, 4000.0)]);
        mon.record("m", 0, 11_000.0);
        mon.record("m", 1, 44_000.0);
        let rep = mon.report(0.25);
        let j = Json::parse(&rep.to_json().to_string()).expect("valid json");
        assert_eq!(j.get("flagged").and_then(|v| v.as_i64()), Some(0));
        let nodes = j.get("nodes").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(nodes.len(), 2);
        // the shared NodeCost fields are present on every record
        for n in nodes {
            for key in ["node", "cycles", "latency_us", "energy_uj", "arena_bytes"] {
                assert!(n.get(key).is_some(), "missing {key}");
            }
        }
        let fit = j.get("fit").unwrap();
        assert!((fit.get("ns_per_cycle").unwrap().as_f64().unwrap() - 11.0).abs() < 1e-9);
    }

    #[test]
    fn zoo_plans_produce_positive_costs() {
        use crate::analytic::Primitive;
        use crate::models::mcunet;
        let cfg = McuConfig::default();
        let graph = Graph::from_model(&mcunet(Primitive::Standard, 42));
        let plan = ExecPlan::compile_graph_default(&graph, true);
        let costs = plan_node_costs(&graph, &plan.candidates(), &plan, &cfg);
        assert_eq!(costs.len(), graph.nodes.len());
        for c in &costs {
            assert!(c.cycles > 0.0, "node {} has zero predicted cycles", c.node);
            assert!(c.latency_us > 0.0);
            assert!(c.mem_accesses > 0);
        }
        // plan step names and cost names line up
        let names = plan.node_names();
        assert_eq!(names.len(), costs.len());
        for (c, n) in costs.iter().zip(&names) {
            assert_eq!(c.node, *n);
        }
    }
}
