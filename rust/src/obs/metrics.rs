//! Sharded metrics registry: counters, gauges and fixed log₂-bucketed
//! histograms, recorded lock-free on the hot path and merged at scrape
//! time into a Prometheus-style text exposition plus a JSON form.
//!
//! Layout follows the serving engine's threading model: the registry
//! owns one [`Shard`] per recording thread (shard 0 is the frontend /
//! submitter side, shards 1..=N belong to the N workers), every shard
//! holds the full set of instruments preallocated at spawn, and a
//! record is a single relaxed atomic add on the recording thread's own
//! shard — no locks, no allocation, no cross-core contention. A scrape
//! walks all shards and sums: counters and histogram buckets add
//! exactly; gauges also add, which is correct under the convention that
//! exactly one shard writes any given gauge (the server's `queue_depth`
//! is written only by the frontend shard).
//!
//! Histograms use 32 fixed power-of-two buckets: an observation `v`
//! lands in bucket `floor(log2(v))` (bucket 0 also catches 0 and 1),
//! clamped to the last bucket, so bucket `i` spans `[2^i, 2^(i+1))`.
//! That covers u64 microsecond latencies from 1 µs to ~1.2 hours with a
//! fixed footprint and a ≤2× relative quantization error, while the
//! exact `sum`/`count` pair keeps the mean error-free.

use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::util::json::Json;

/// Number of log₂ buckets per histogram (fixed at construction).
pub const HIST_BUCKETS: usize = 32;

/// Bucket index for an observation: `floor(log2(v))` clamped to the
/// last bucket; 0 and 1 both land in bucket 0.
#[inline]
fn bucket_of(v: u64) -> usize {
    if v <= 1 {
        0
    } else {
        (63 - v.leading_zeros() as usize).min(HIST_BUCKETS - 1)
    }
}

/// Inclusive upper bound of bucket `i` (the Prometheus `le` label).
#[inline]
fn bucket_le(i: usize) -> u64 {
    if i + 1 >= 64 {
        u64::MAX
    } else {
        (1u64 << (i + 1)) - 1
    }
}

/// One histogram: exact count/sum plus fixed log₂ buckets.
#[derive(Debug)]
struct Hist {
    count: AtomicU64,
    sum: AtomicU64,
    buckets: Vec<AtomicU64>,
}

impl Hist {
    fn new() -> Self {
        Self {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            buckets: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    fn observe(&self, v: u64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
    }
}

/// One recording thread's slice of the registry. All instruments are
/// preallocated when the registry is built; recording is a relaxed
/// atomic add — no locks, no allocation.
#[derive(Debug)]
pub struct Shard {
    counters: Vec<AtomicU64>,
    gauges: Vec<AtomicU64>,
    hists: Vec<Hist>,
}

impl Shard {
    fn new(n_counters: usize, n_gauges: usize, n_hists: usize) -> Self {
        Self {
            counters: (0..n_counters).map(|_| AtomicU64::new(0)).collect(),
            gauges: (0..n_gauges).map(|_| AtomicU64::new(0)).collect(),
            hists: (0..n_hists).map(|_| Hist::new()).collect(),
        }
    }

    /// Add `n` to counter `idx` (indices come from registry order).
    #[inline]
    pub fn counter_add(&self, idx: usize, n: u64) {
        self.counters[idx].fetch_add(n, Ordering::Relaxed);
    }

    /// Set gauge `idx` to `v`. By convention a given gauge has exactly
    /// one writing shard so the scrape-time sum reads back `v`.
    #[inline]
    pub fn gauge_set(&self, idx: usize, v: u64) {
        self.gauges[idx].store(v, Ordering::Relaxed);
    }

    /// Record one observation into histogram `idx`.
    #[inline]
    pub fn observe(&self, idx: usize, v: u64) {
        self.hists[idx].observe(v);
    }
}

/// Point-in-time merge of one histogram across all shards.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct HistSnapshot {
    /// Total observations.
    pub count: u64,
    /// Exact sum of all observations.
    pub sum: u64,
    /// Per-bucket counts (`buckets[i]` spans `[2^i, 2^(i+1))`).
    pub buckets: Vec<u64>,
}

impl HistSnapshot {
    /// Exact mean of the observations (0.0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }
}

/// Point-in-time merge of every instrument across all shards.
#[derive(Clone, Debug, Default)]
pub struct Snapshot {
    /// `(name, summed value)` per counter, in registration order.
    pub counters: Vec<(&'static str, u64)>,
    /// `(name, summed value)` per gauge, in registration order.
    pub gauges: Vec<(&'static str, u64)>,
    /// `(name, merged histogram)` per histogram, in registration order.
    pub hists: Vec<(&'static str, HistSnapshot)>,
}

impl Snapshot {
    /// Value of a counter by name (`None` if unregistered).
    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters.iter().find(|(n, _)| *n == name).map(|&(_, v)| v)
    }

    /// Merged histogram by name (`None` if unregistered).
    pub fn hist(&self, name: &str) -> Option<&HistSnapshot> {
        self.hists.iter().find(|(n, _)| *n == name).map(|(_, h)| h)
    }

    /// Prometheus text exposition (format version 0.0.4). Histogram
    /// buckets are emitted cumulatively with power-of-two `le` labels,
    /// truncated after the highest non-empty bucket, then `+Inf`.
    pub fn to_prometheus(&self, namespace: &str) -> String {
        let mut s = String::new();
        for (name, v) in &self.counters {
            let _ = writeln!(s, "# TYPE {namespace}_{name} counter");
            let _ = writeln!(s, "{namespace}_{name} {v}");
        }
        for (name, v) in &self.gauges {
            let _ = writeln!(s, "# TYPE {namespace}_{name} gauge");
            let _ = writeln!(s, "{namespace}_{name} {v}");
        }
        for (name, h) in &self.hists {
            let _ = writeln!(s, "# TYPE {namespace}_{name} histogram");
            let last = h.buckets.iter().rposition(|&c| c > 0).unwrap_or(0);
            let mut cum = 0u64;
            for (i, &c) in h.buckets.iter().enumerate().take(last + 1) {
                cum += c;
                let _ = writeln!(s, "{namespace}_{name}_bucket{{le=\"{}\"}} {cum}", bucket_le(i));
            }
            let _ = writeln!(s, "{namespace}_{name}_bucket{{le=\"+Inf\"}} {}", h.count);
            let _ = writeln!(s, "{namespace}_{name}_sum {}", h.sum);
            let _ = writeln!(s, "{namespace}_{name}_count {}", h.count);
        }
        s
    }

    /// JSON form of the same merged view (parseable by
    /// [`crate::util::json::Json::parse`]).
    pub fn to_json(&self) -> Json {
        let mut counters = Json::obj();
        for (name, v) in &self.counters {
            counters = counters.field(name, *v);
        }
        let mut gauges = Json::obj();
        for (name, v) in &self.gauges {
            gauges = gauges.field(name, *v);
        }
        let mut hists = Json::obj();
        for (name, h) in &self.hists {
            let buckets: Vec<Json> = h.buckets.iter().map(|&c| Json::from(c)).collect();
            hists = hists.field(
                name,
                Json::obj()
                    .field("count", h.count)
                    .field("sum", h.sum)
                    .field("mean", h.mean())
                    .field("buckets", Json::Arr(buckets)),
            );
        }
        Json::obj()
            .field("counters", counters)
            .field("gauges", gauges)
            .field("histograms", hists)
    }
}

/// Metric names plus per-thread shards. Built once at server spawn;
/// instruments are addressed by their registration index (cheap and
/// allocation-free on the record path), names only matter at scrape.
#[derive(Debug)]
pub struct Registry {
    counter_names: Vec<&'static str>,
    gauge_names: Vec<&'static str>,
    hist_names: Vec<&'static str>,
    shards: Vec<Arc<Shard>>,
}

impl Registry {
    /// Build a registry with the given instrument names and `n_shards`
    /// preallocated shards (one per recording thread).
    pub fn new(
        counters: &[&'static str],
        gauges: &[&'static str],
        hists: &[&'static str],
        n_shards: usize,
    ) -> Self {
        assert!(n_shards > 0, "registry needs at least one shard");
        Self {
            counter_names: counters.to_vec(),
            gauge_names: gauges.to_vec(),
            hist_names: hists.to_vec(),
            shards: (0..n_shards)
                .map(|_| Arc::new(Shard::new(counters.len(), gauges.len(), hists.len())))
                .collect(),
        }
    }

    /// Number of shards.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Handle to shard `i` for a recording thread to keep.
    pub fn shard(&self, i: usize) -> Arc<Shard> {
        Arc::clone(&self.shards[i])
    }

    /// Sum of counter `idx` across all shards.
    pub fn counter(&self, idx: usize) -> u64 {
        self.shards
            .iter()
            .map(|s| s.counters[idx].load(Ordering::Relaxed))
            .sum()
    }

    /// Merge every instrument across all shards.
    pub fn snapshot(&self) -> Snapshot {
        let counters = self
            .counter_names
            .iter()
            .enumerate()
            .map(|(i, &name)| (name, self.counter(i)))
            .collect();
        let gauges = self
            .gauge_names
            .iter()
            .enumerate()
            .map(|(i, &name)| {
                let v = self
                    .shards
                    .iter()
                    .map(|s| s.gauges[i].load(Ordering::Relaxed))
                    .sum();
                (name, v)
            })
            .collect();
        let hists = self
            .hist_names
            .iter()
            .enumerate()
            .map(|(i, &name)| {
                let mut h = HistSnapshot {
                    count: 0,
                    sum: 0,
                    buckets: vec![0u64; HIST_BUCKETS],
                };
                for s in &self.shards {
                    h.count += s.hists[i].count.load(Ordering::Relaxed);
                    h.sum += s.hists[i].sum.load(Ordering::Relaxed);
                    for (acc, b) in h.buckets.iter_mut().zip(&s.hists[i].buckets) {
                        *acc += b.load(Ordering::Relaxed);
                    }
                }
                (name, h)
            })
            .collect();
        Snapshot {
            counters,
            gauges,
            hists,
        }
    }
}

/// Validate a metrics JSON document produced by [`Snapshot::to_json`]:
/// the three sections exist, every histogram's buckets sum to its
/// count, and at least one request was served.
pub fn validate_metrics_json(j: &Json) -> Result<(), String> {
    let counters = j
        .get("counters")
        .and_then(|v| v.as_obj())
        .ok_or("missing counters object")?;
    j.get("gauges")
        .and_then(|v| v.as_obj())
        .ok_or("missing gauges object")?;
    let hists = j
        .get("histograms")
        .and_then(|v| v.as_obj())
        .ok_or("missing histograms object")?;
    for (name, h) in hists {
        let count = h
            .get("count")
            .and_then(|v| v.as_i64())
            .ok_or_else(|| format!("histogram {name} lacks a count"))?;
        let buckets = h
            .get("buckets")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| format!("histogram {name} lacks buckets"))?;
        let total: i64 = buckets.iter().filter_map(|b| b.as_i64()).sum();
        if total != count {
            return Err(format!(
                "histogram {name}: buckets sum to {total}, count says {count}"
            ));
        }
    }
    let served = counters
        .iter()
        .find(|(k, _)| k == "requests_served_total")
        .and_then(|(_, v)| v.as_i64())
        .ok_or("missing requests_served_total counter")?;
    if served < 1 {
        return Err("requests_served_total is zero".into());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_edges() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 0);
        assert_eq!(bucket_of(2), 1);
        assert_eq!(bucket_of(3), 1);
        assert_eq!(bucket_of(4), 2);
        assert_eq!(bucket_of(1023), 9);
        assert_eq!(bucket_of(1024), 10);
        assert_eq!(bucket_of(u64::MAX), HIST_BUCKETS - 1);
        assert_eq!(bucket_le(0), 1);
        assert_eq!(bucket_le(1), 3);
        assert_eq!(bucket_le(9), 1023);
    }

    #[test]
    fn shards_merge_at_scrape() {
        let r = Registry::new(&["served"], &["depth"], &["lat_us"], 3);
        r.shard(0).counter_add(0, 2);
        r.shard(1).counter_add(0, 3);
        r.shard(2).counter_add(0, 5);
        r.shard(0).gauge_set(0, 7);
        r.shard(1).observe(0, 100);
        r.shard(2).observe(0, 100);
        r.shard(2).observe(0, 5000);
        let snap = r.snapshot();
        assert_eq!(snap.counter("served"), Some(10));
        assert_eq!(snap.gauges[0], ("depth", 7));
        let h = snap.hist("lat_us").unwrap();
        assert_eq!(h.count, 3);
        assert_eq!(h.sum, 5200);
        assert_eq!(h.buckets[bucket_of(100)], 2);
        assert_eq!(h.buckets[bucket_of(5000)], 1);
        assert!((h.mean() - 5200.0 / 3.0).abs() < 1e-9);
        assert_eq!(r.counter(0), 10);
    }

    #[test]
    fn prometheus_exposition_shape() {
        let r = Registry::new(&["served"], &["depth"], &["lat_us"], 1);
        r.shard(0).counter_add(0, 4);
        r.shard(0).observe(0, 3);
        r.shard(0).observe(0, 9);
        let text = r.snapshot().to_prometheus("convbench");
        assert!(text.contains("# TYPE convbench_served counter"));
        assert!(text.contains("convbench_served 4"));
        assert!(text.contains("# TYPE convbench_lat_us histogram"));
        // cumulative buckets: le=3 covers the 3, le=15 covers both
        assert!(text.contains("convbench_lat_us_bucket{le=\"3\"} 1"));
        assert!(text.contains("convbench_lat_us_bucket{le=\"15\"} 2"));
        assert!(text.contains("convbench_lat_us_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("convbench_lat_us_sum 12"));
        assert!(text.contains("convbench_lat_us_count 2"));
    }

    #[test]
    fn json_round_trips_and_validates() {
        let r = Registry::new(
            &["requests_served_total", "requests_shed_total"],
            &["queue_depth"],
            &["batch_size"],
            2,
        );
        r.shard(0).counter_add(0, 6);
        r.shard(1).observe(0, 4);
        let text = r.snapshot().to_json().to_string();
        let j = Json::parse(&text).expect("valid json");
        validate_metrics_json(&j).expect("valid metrics");
        let served = j
            .get("counters")
            .and_then(|c| c.get("requests_served_total"))
            .and_then(|v| v.as_i64());
        assert_eq!(served, Some(6));
    }

    #[test]
    fn validation_rejects_empty_and_inconsistent() {
        let r = Registry::new(&["requests_served_total"], &[], &["batch_size"], 1);
        let j = Json::parse(&r.snapshot().to_json().to_string()).unwrap();
        assert!(validate_metrics_json(&j).is_err(), "zero served must fail");
        let bad = Json::parse(
            r#"{"counters":{"requests_served_total":1},"gauges":{},
                "histograms":{"h":{"count":2,"sum":0,"buckets":[1]}}}"#,
        )
        .unwrap();
        assert!(validate_metrics_json(&bad).is_err(), "bucket/count mismatch must fail");
    }
}
