//! Observability for the serving engine, in three pillars (see
//! docs/ARCHITECTURE.md "Observability"):
//!
//! 1. **Metrics** ([`metrics`]): a sharded registry of counters, gauges
//!    and log₂-bucketed histograms — lock-free relaxed-atomic recording
//!    on per-worker shards, merged at scrape into Prometheus text and a
//!    JSON form.
//! 2. **Tracing** ([`trace`]): a request → queue-wait → batch-drain →
//!    per-node exec → respond span model, recorded into preallocated
//!    per-worker rings at a 1-in-N batch sampling rate and exported as
//!    Chrome trace-event JSON (Perfetto-loadable). The engine hooks are
//!    a [`TraceSink`] type parameter on [`crate::nn::ExecPlan`]'s run
//!    loops whose no-op instantiation monomorphizes to nothing, exactly
//!    like [`crate::nn::NoopMonitor`].
//! 3. **Drift** ([`drift`]): per-(model, node) measured host time
//!    against the analytic cycle prediction, with a model-wide linear
//!    fit and per-node departure flags — the paper's MACs↔latency
//!    linearity claim (§4.1) evaluated continuously at runtime.

pub mod drift;
pub mod metrics;
pub mod trace;

pub use drift::{plan_node_costs, DriftMonitor, DriftRecord, DriftReport, NodeCost};
pub use metrics::{validate_metrics_json, HistSnapshot, Registry, Shard, Snapshot, HIST_BUCKETS};
pub use trace::{
    chrome_trace_json, validate_chrome_trace, ExecTracer, NodeTiming, NoopTraceSink, SpanKind,
    TraceEvent, TraceModelMeta, TraceRing, TraceSink,
};
