//! Sampled request tracing: a span model for the serving engine
//! (request → queue-wait → batch-drain → per-node exec → respond),
//! recorded into preallocated per-worker ring buffers and exported as
//! Chrome trace-event JSON (loadable in Perfetto or `chrome://tracing`).
//!
//! The per-node hooks follow the [`crate::nn::Monitor`] discipline: the
//! engine's run loops are generic over a [`TraceSink`], the trait's
//! methods have empty inline default bodies, and [`NoopTraceSink`]
//! overrides nothing — so the untraced instantiation monomorphizes to
//! exactly the code that existed before tracing, and the hot-path
//! zero-allocation and event-stream-identity pins in
//! `benches/infer_hot.rs` keep holding with tracing compiled in.
//!
//! Timestamps are `f64` microseconds relative to a caller-chosen epoch
//! (the server uses its spawn instant), which is both what the Chrome
//! trace-event format wants in its `ts`/`dur` fields and precise to
//! well under a nanosecond for any realistic process lifetime.

use std::time::Instant;

use crate::util::json::Json;

/// Per-node wall-time hooks on the engine's run loops. The default
/// bodies are empty and `#[inline(always)]`, so a sink that overrides
/// nothing costs nothing.
pub trait TraceSink {
    /// Node `idx` (step index in the plan) is about to execute.
    #[inline(always)]
    fn node_start(&mut self, _idx: usize, _name: &'static str) {}
    /// Node `idx` finished executing.
    #[inline(always)]
    fn node_end(&mut self, _idx: usize, _name: &'static str) {}
}

/// Zero-cost sink for the untraced hot path (the [`crate::nn::NoopMonitor`]
/// of tracing).
#[derive(Clone, Copy, Debug, Default)]
pub struct NoopTraceSink;
impl TraceSink for NoopTraceSink {}

/// One timed node execution captured by [`ExecTracer`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NodeTiming {
    /// Step index in the plan.
    pub node: u16,
    /// Start, µs since the tracer's epoch.
    pub start_us: f64,
    /// Duration, µs.
    pub dur_us: f64,
}

/// A [`TraceSink`] that records per-node wall times into a
/// preallocated buffer. `reset()` between inferences keeps the buffer's
/// capacity, so steady-state recording is allocation-free; timings past
/// capacity are counted as dropped rather than grown into.
#[derive(Debug)]
pub struct ExecTracer {
    epoch: Instant,
    open_start: Instant,
    timings: Vec<NodeTiming>,
    dropped: u64,
}

impl ExecTracer {
    /// Tracer with room for `cap` node timings (e.g. plan node count ×
    /// batch lanes), all allocated up front.
    pub fn with_capacity(epoch: Instant, cap: usize) -> Self {
        Self {
            epoch,
            open_start: epoch,
            timings: Vec::with_capacity(cap),
            dropped: 0,
        }
    }

    /// Clear recorded timings for the next inference. Keeps capacity.
    pub fn reset(&mut self) {
        self.timings.clear();
        self.dropped = 0;
    }

    /// Timings recorded since the last [`ExecTracer::reset`].
    pub fn timings(&self) -> &[NodeTiming] {
        &self.timings
    }

    /// Node executions that did not fit the preallocated buffer.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }
}

impl TraceSink for ExecTracer {
    #[inline(always)]
    fn node_start(&mut self, _idx: usize, _name: &'static str) {
        self.open_start = Instant::now();
    }

    #[inline(always)]
    fn node_end(&mut self, idx: usize, _name: &'static str) {
        let dur = self.open_start.elapsed();
        if self.timings.len() < self.timings.capacity() {
            let start = self.open_start.duration_since(self.epoch);
            self.timings.push(NodeTiming {
                node: idx as u16,
                start_us: start.as_secs_f64() * 1e6,
                dur_us: dur.as_secs_f64() * 1e6,
            });
        } else {
            self.dropped += 1;
        }
    }
}

/// Span taxonomy for one served request (see docs/ARCHITECTURE.md).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// Submission to reply send, one per request.
    Request,
    /// Enqueue to batch-drain start, one per request.
    QueueWait,
    /// Stage + execute of one drained micro-batch, one per batch.
    BatchDrain,
    /// One node (plan step) execution inside a batch drain.
    ExecNode,
    /// Reply fan-out for one drained batch.
    Respond,
}

impl SpanKind {
    /// Stable span name used in trace events and validation.
    pub fn name(&self) -> &'static str {
        match self {
            SpanKind::Request => "request",
            SpanKind::QueueWait => "queue_wait",
            SpanKind::BatchDrain => "batch_drain",
            SpanKind::ExecNode => "exec_node",
            SpanKind::Respond => "respond",
        }
    }
}

/// One recorded span. `detail` is kind-dependent: the request id for
/// `Request`/`QueueWait`, the node index for `ExecNode`, and the batch
/// size for `BatchDrain`/`Respond`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceEvent {
    /// Which span this is.
    pub kind: SpanKind,
    /// Start, µs since the server epoch.
    pub ts_us: f64,
    /// Duration, µs.
    pub dur_us: f64,
    /// Recording thread (0 = frontend, 1.. = workers).
    pub tid: u32,
    /// Model index into the server's sorted model table.
    pub model: u16,
    /// Kind-dependent payload (see type docs).
    pub detail: u64,
}

/// Fixed-capacity ring of trace events: preallocated at worker spawn,
/// overwrites the oldest events when full (dropping history, never
/// growing), drained oldest-first by the exporter.
#[derive(Debug)]
pub struct TraceRing {
    cap: usize,
    buf: Vec<TraceEvent>,
    head: usize,
    dropped: u64,
}

impl TraceRing {
    /// Ring with room for `cap` events (> 0), allocated up front.
    pub fn with_capacity(cap: usize) -> Self {
        assert!(cap > 0, "trace ring capacity must be positive");
        Self {
            cap,
            buf: Vec::with_capacity(cap),
            head: 0,
            dropped: 0,
        }
    }

    /// Record one span. O(1), allocation-free; overwrites the oldest
    /// event once the ring is full.
    pub fn push(&mut self, e: TraceEvent) {
        if self.buf.len() < self.cap {
            self.buf.push(e);
        } else {
            self.buf[self.head] = e;
            self.dropped += 1;
        }
        self.head = (self.head + 1) % self.cap;
    }

    /// Buffered event count.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True when nothing is buffered.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Events overwritten before they could be drained.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Take all buffered events, oldest first, leaving the ring empty
    /// (capacity retained).
    pub fn drain(&mut self) -> Vec<TraceEvent> {
        let mut out = Vec::with_capacity(self.buf.len());
        if self.buf.len() < self.cap {
            out.extend_from_slice(&self.buf);
        } else {
            out.extend_from_slice(&self.buf[self.head..]);
            out.extend_from_slice(&self.buf[..self.head]);
        }
        self.buf.clear();
        self.head = 0;
        out
    }
}

/// Per-model naming metadata the Chrome exporter resolves span labels
/// from: the model's name and its plan's per-node kernel names.
#[derive(Clone, Debug)]
pub struct TraceModelMeta {
    /// Model name (the serving registry key).
    pub name: String,
    /// Kernel name per plan step, in step order.
    pub nodes: Vec<&'static str>,
}

/// Render recorded spans as a Chrome trace-event JSON document
/// (`{"traceEvents": [...]}` with complete `ph:"X"` events), loadable
/// in Perfetto. `models[e.model]` supplies display names; events with
/// out-of-range model indices fall back to the raw index.
pub fn chrome_trace_json(events: &[TraceEvent], models: &[TraceModelMeta]) -> Json {
    let out: Vec<Json> = events
        .iter()
        .map(|e| {
            let meta = models.get(e.model as usize);
            let model_name = match meta {
                Some(m) => m.name.clone(),
                None => format!("model#{}", e.model),
            };
            let name = match e.kind {
                SpanKind::ExecNode => meta
                    .and_then(|m| m.nodes.get(e.detail as usize).copied())
                    .unwrap_or("node"),
                k => k.name(),
            };
            let mut args = Json::obj().field("model", model_name);
            args = match e.kind {
                SpanKind::Request | SpanKind::QueueWait => args.field("request_id", e.detail),
                SpanKind::ExecNode => args.field("node_index", e.detail),
                SpanKind::BatchDrain | SpanKind::Respond => args.field("batch_size", e.detail),
            };
            Json::obj()
                .field("name", name)
                .field("cat", e.kind.name())
                .field("ph", "X")
                .field("ts", e.ts_us)
                .field("dur", e.dur_us)
                .field("pid", 1u64)
                .field("tid", u64::from(e.tid))
                .field("args", args)
        })
        .collect();
    Json::obj()
        .field("traceEvents", Json::Arr(out))
        .field("displayTimeUnit", "ms")
}

/// Timestamp slack (µs) allowed between spans that were computed from
/// the same instants but rounded independently to f64 microseconds.
const TS_EPS_US: f64 = 2.0;

fn span_f64(e: &Json, key: &str) -> Option<f64> {
    e.get(key).and_then(|v| v.as_f64())
}

fn arg_str<'a>(e: &'a Json, key: &str) -> Option<&'a str> {
    e.get("args").and_then(|a| a.get(key)).and_then(|v| v.as_str())
}

fn arg_i64(e: &Json, key: &str) -> Option<i64> {
    e.get("args").and_then(|a| a.get(key)).and_then(|v| v.as_i64())
}

fn cat(e: &Json) -> Option<&str> {
    e.get("cat").and_then(|v| v.as_str())
}

/// Validate a Chrome trace produced by [`chrome_trace_json`]: every
/// event is a complete (`ph:"X"`) span with finite non-negative
/// timestamps, and at least one request span is *complete* — its
/// queue-wait ends where a batch-drain for the same model begins, that
/// batch contains at least one per-node exec span, and the request
/// envelope covers the batch, all monotonically ordered.
pub fn validate_chrome_trace(j: &Json) -> Result<(), String> {
    let events = j
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .ok_or("missing traceEvents array")?;
    if events.is_empty() {
        return Err("empty traceEvents".into());
    }
    for (i, e) in events.iter().enumerate() {
        if e.get("ph").and_then(|v| v.as_str()) != Some("X") {
            return Err(format!("event {i}: not a complete (ph=X) span"));
        }
        let ts = span_f64(e, "ts").ok_or_else(|| format!("event {i}: missing ts"))?;
        let dur = span_f64(e, "dur").ok_or_else(|| format!("event {i}: missing dur"))?;
        if !ts.is_finite() || !dur.is_finite() || ts < 0.0 || dur < 0.0 {
            return Err(format!("event {i}: bad ts/dur ({ts}, {dur})"));
        }
    }
    let requests: Vec<&Json> = events.iter().filter(|e| cat(e) == Some("request")).collect();
    if requests.is_empty() {
        return Err("no request spans".into());
    }
    for r in &requests {
        if request_is_complete(r, events) {
            return Ok(());
        }
    }
    Err("no complete request span (queue-wait → batch-drain → exec-node nesting) found".into())
}

/// True when `r`'s queue-wait, batch-drain and per-node exec spans are
/// all present and monotonically nested.
fn request_is_complete(r: &Json, events: &[Json]) -> bool {
    let (Some(id), Some(model)) = (arg_i64(r, "request_id"), arg_str(r, "model")) else {
        return false;
    };
    let (Some(r_ts), Some(r_dur)) = (span_f64(r, "ts"), span_f64(r, "dur")) else {
        return false;
    };
    // the request's queue-wait: same id, starts with the request
    let Some(q) = events.iter().find(|e| {
        cat(e) == Some("queue_wait")
            && arg_i64(e, "request_id") == Some(id)
            && span_f64(e, "ts").is_some_and(|t| (t - r_ts).abs() <= TS_EPS_US)
    }) else {
        return false;
    };
    let q_end = span_f64(q, "ts").unwrap_or(0.0) + span_f64(q, "dur").unwrap_or(0.0);
    // the batch the request rode in starts exactly where its wait ends
    let Some(b) = events.iter().find(|e| {
        cat(e) == Some("batch_drain")
            && arg_str(e, "model") == Some(model)
            && span_f64(e, "ts").is_some_and(|t| (t - q_end).abs() <= TS_EPS_US)
    }) else {
        return false;
    };
    let (Some(b_ts), Some(b_dur)) = (span_f64(b, "ts"), span_f64(b, "dur")) else {
        return false;
    };
    // at least one per-node exec span nested inside the batch drain
    let has_exec = events.iter().any(|e| {
        cat(e) == Some("exec_node")
            && arg_str(e, "model") == Some(model)
            && span_f64(e, "ts").is_some_and(|t| t + TS_EPS_US >= b_ts)
            && span_f64(e, "ts").zip(span_f64(e, "dur")).is_some_and(|(t, d)| {
                t + d <= b_ts + b_dur + TS_EPS_US
            })
    });
    // the request envelope covers the whole batch
    has_exec && r_ts <= b_ts + TS_EPS_US && r_ts + r_dur + TS_EPS_US >= b_ts + b_dur
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(kind: SpanKind, ts_us: f64, dur_us: f64, model: u16, detail: u64) -> TraceEvent {
        TraceEvent {
            kind,
            ts_us,
            dur_us,
            tid: 1,
            model,
            detail,
        }
    }

    fn meta() -> Vec<TraceModelMeta> {
        vec![TraceModelMeta {
            name: "mcunet-standard".into(),
            nodes: vec!["conv3x3", "relu", "dense"],
        }]
    }

    /// A minimal complete request: wait 10..20, batch 20..50 with one
    /// node span inside, respond after, request envelope 10..55.
    fn complete_request() -> Vec<TraceEvent> {
        vec![
            ev(SpanKind::QueueWait, 10.0, 10.0, 0, 42),
            ev(SpanKind::BatchDrain, 20.0, 30.0, 0, 2),
            ev(SpanKind::ExecNode, 21.0, 8.0, 0, 0),
            ev(SpanKind::ExecNode, 29.5, 15.0, 0, 2),
            ev(SpanKind::Respond, 50.0, 4.0, 0, 2),
            ev(SpanKind::Request, 10.0, 45.0, 0, 42),
        ]
    }

    #[test]
    fn ring_preserves_order_and_wraps() {
        let mut r = TraceRing::with_capacity(3);
        assert!(r.is_empty());
        for i in 0..5 {
            r.push(ev(SpanKind::Request, i as f64, 1.0, 0, i));
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.dropped(), 2);
        let out = r.drain();
        let ids: Vec<u64> = out.iter().map(|e| e.detail).collect();
        assert_eq!(ids, vec![2, 3, 4], "oldest-first after wrap");
        assert!(r.is_empty());
        assert_eq!(r.drain().len(), 0);
    }

    #[test]
    fn tracer_records_and_resets_without_regrowing() {
        let mut t = ExecTracer::with_capacity(Instant::now(), 2);
        t.node_start(0, "a");
        t.node_end(0, "a");
        t.node_start(1, "b");
        t.node_end(1, "b");
        t.node_start(2, "c");
        t.node_end(2, "c");
        assert_eq!(t.timings().len(), 2, "third timing must drop, not grow");
        assert_eq!(t.dropped(), 1);
        assert_eq!(t.timings()[0].node, 0);
        assert!(t.timings().iter().all(|n| n.start_us >= 0.0 && n.dur_us >= 0.0));
        let cap0 = t.timings.capacity();
        t.reset();
        assert!(t.timings().is_empty());
        assert_eq!(t.dropped(), 0);
        assert_eq!(t.timings.capacity(), cap0);
    }

    #[test]
    fn chrome_export_validates() {
        let j = chrome_trace_json(&complete_request(), &meta());
        let text = j.to_string();
        let parsed = Json::parse(&text).expect("valid json");
        validate_chrome_trace(&parsed).expect("complete trace");
        // node names resolve through the model metadata
        assert!(text.contains("\"conv3x3\""));
        assert!(text.contains("\"dense\""));
        assert!(text.contains("mcunet-standard"));
    }

    #[test]
    fn validation_rejects_incomplete_traces() {
        // no exec span inside the batch window
        let mut evs = complete_request();
        evs.retain(|e| e.kind != SpanKind::ExecNode);
        let j = chrome_trace_json(&evs, &meta());
        assert!(validate_chrome_trace(&j).is_err());
        // queue-wait does not butt up against any batch drain
        let mut evs = complete_request();
        evs[0].dur_us = 3.0;
        let j = chrome_trace_json(&evs, &meta());
        assert!(validate_chrome_trace(&j).is_err());
        // empty trace
        let j = chrome_trace_json(&[], &meta());
        assert!(validate_chrome_trace(&j).is_err());
        // negative duration
        let mut evs = complete_request();
        evs[1].dur_us = -1.0;
        let j = chrome_trace_json(&evs, &meta());
        assert!(validate_chrome_trace(&j).is_err());
    }
}
