//! Fixed-point helpers shared by the engine's SIMD path: packing int8/int16
//! lanes into 32-bit registers and the ARM DSP-extension intrinsics the
//! CMSIS-NN kernels rely on (`__SMLAD`, `__SXTB16`, `__PKHBT`, …), emulated
//! bit-exactly. The emulation preserves the *memory-access structure*
//! (one 32-bit load replaces two 16-bit / four 8-bit loads), which is what
//! drives the paper's Fig. 3 data-reuse analysis.

/// Pack two i16 values into a u32 as the Cortex-M register would hold them
/// (low halfword first — little-endian lane order).
#[inline(always)]
pub fn pack_i16x2(lo: i16, hi: i16) -> u32 {
    (lo as u16 as u32) | ((hi as u16 as u32) << 16)
}

/// Unpack a u32 into (low, high) i16 lanes.
#[inline(always)]
pub fn unpack_i16x2(x: u32) -> (i16, i16) {
    (x as u16 as i16, (x >> 16) as u16 as i16)
}

/// Pack four i8 values into a u32 (byte 0 = lane 0).
#[inline(always)]
pub fn pack_i8x4(b: [i8; 4]) -> u32 {
    u32::from_le_bytes([b[0] as u8, b[1] as u8, b[2] as u8, b[3] as u8])
}

/// Unpack a u32 into four i8 lanes.
#[inline(always)]
pub fn unpack_i8x4(x: u32) -> [i8; 4] {
    let b = x.to_le_bytes();
    [b[0] as i8, b[1] as i8, b[2] as i8, b[3] as i8]
}

/// `__SMLAD`: dual signed 16×16 multiply-accumulate.
/// `acc + lo(x)·lo(y) + hi(x)·hi(y)` — one cycle on Cortex-M4, two MACs.
#[inline(always)]
pub fn smlad(x: u32, y: u32, acc: i32) -> i32 {
    let (xl, xh) = unpack_i16x2(x);
    let (yl, yh) = unpack_i16x2(y);
    acc.wrapping_add(xl as i32 * yl as i32)
        .wrapping_add(xh as i32 * yh as i32)
}

/// `__SXTB16`: sign-extend bytes 0 and 2 of a word into two i16 lanes.
/// CMSIS-NN uses `__SXTB16(x)` / `__SXTB16(__ROR(x, 8))` to widen a word
/// of four q7 values into two words of q15 pairs.
#[inline(always)]
pub fn sxtb16(x: u32) -> u32 {
    let b = x.to_le_bytes();
    pack_i16x2(b[0] as i8 as i16, b[2] as i8 as i16)
}

/// `__ROR`: rotate right.
#[inline(always)]
pub fn ror(x: u32, n: u32) -> u32 {
    x.rotate_right(n)
}

/// Widen four q7 bytes (one 32-bit load) into two q15 pair-words, in the
/// lane order CMSIS-NN's `arm_nn_read_q7x4` + `__SXTB16` sequence yields:
/// returns (word with lanes (b0, b2), word with lanes (b1, b3)).
#[inline(always)]
pub fn q7x4_to_q15x2(x: u32) -> (u32, u32) {
    (sxtb16(x), sxtb16(ror(x, 8)))
}

/// `__SSAT(x, 8)` — saturate to signed 8-bit.
#[inline(always)]
pub fn ssat8(x: i32) -> i32 {
    x.clamp(-128, 127)
}

/// `__QADD16`-style element-wise i16 saturating add on packed lanes
/// (used by the int16 batch-norm path of add-convolution).
#[inline(always)]
pub fn qadd16(x: u32, y: u32) -> u32 {
    let (xl, xh) = unpack_i16x2(x);
    let (yl, yh) = unpack_i16x2(y);
    let sl = (xl as i32 + yl as i32).clamp(i16::MIN as i32, i16::MAX as i32) as i16;
    let sh = (xh as i32 + yh as i32).clamp(i16::MIN as i32, i16::MAX as i32) as i16;
    pack_i16x2(sl, sh)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, ensure};

    #[test]
    fn pack_unpack_i16_roundtrip() {
        for &(a, b) in &[(0i16, 0i16), (-1, 1), (i16::MIN, i16::MAX), (12345, -12345)] {
            assert_eq!(unpack_i16x2(pack_i16x2(a, b)), (a, b));
        }
    }

    #[test]
    fn pack_unpack_i8_roundtrip() {
        let cases = [[0i8, 0, 0, 0], [-1, 1, -128, 127], [5, -6, 7, -8]];
        for c in cases {
            assert_eq!(unpack_i8x4(pack_i8x4(c)), c);
        }
    }

    #[test]
    fn smlad_matches_scalar() {
        check(
            "smlad",
            512,
            |rng, _| {
                (
                    rng.next_u32(),
                    rng.next_u32(),
                    rng.next_u32() as i32 >> 8,
                )
            },
            |&(x, y, acc)| {
                let (xl, xh) = unpack_i16x2(x);
                let (yl, yh) = unpack_i16x2(y);
                let expect = acc
                    .wrapping_add(xl as i32 * yl as i32)
                    .wrapping_add(xh as i32 * yh as i32);
                ensure(smlad(x, y, acc) == expect, "smlad mismatch")
            },
        );
    }

    #[test]
    fn sxtb16_extends_bytes_0_and_2() {
        let x = pack_i8x4([-3, 100, -128, 7]);
        let (l, h) = unpack_i16x2(sxtb16(x));
        assert_eq!((l, h), (-3, -128));
        let (l, h) = unpack_i16x2(sxtb16(ror(x, 8)));
        assert_eq!((l, h), (100, 7));
    }

    #[test]
    fn q7x4_widen_covers_all_lanes() {
        check(
            "q7x4",
            256,
            |rng, _| [rng.i8(), rng.i8(), rng.i8(), rng.i8()],
            |b| {
                let (even, odd) = q7x4_to_q15x2(pack_i8x4(*b));
                let (e0, e2) = unpack_i16x2(even);
                let (o1, o3) = unpack_i16x2(odd);
                ensure(
                    e0 == b[0] as i16 && e2 == b[2] as i16 && o1 == b[1] as i16 && o3 == b[3] as i16,
                    format!("widen mismatch {b:?}"),
                )
            },
        );
    }

    #[test]
    fn ssat8_range() {
        assert_eq!(ssat8(1000), 127);
        assert_eq!(ssat8(-1000), -128);
        assert_eq!(ssat8(5), 5);
    }

    #[test]
    fn qadd16_saturates() {
        let x = pack_i16x2(i16::MAX, -10);
        let y = pack_i16x2(10, 20);
        let (l, h) = unpack_i16x2(qadd16(x, y));
        assert_eq!((l, h), (i16::MAX, 10));
    }
}
