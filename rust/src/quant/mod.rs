//! Power-of-two symmetric 8-bit quantization — the NNoM scheme the paper
//! uses (§3.1, Eq. 4):
//!
//! ```text
//! dec = ceil(log2(max|X_f|));   x_i = floor(x_f · 2^((8-1)-dec))
//! ```
//!
//! We carry the exponent around as `frac_bits = 7 - dec` (the number of
//! fractional bits of the Q-format), which is what NNoM's generated code
//! actually stores: a value is `x_f ≈ x_i / 2^frac_bits`.
//!
//! Because every scale is a power of two, convolution requantization is a
//! plain arithmetic shift (Alg. 1 left):
//! `out = (Σ x·w) >> (frac_in + frac_w − frac_out)` — no division, no
//! per-channel multipliers. Add-convolution needs the operands *aligned*
//! to a common exponent before the L1-distance is taken (Alg. 1 right);
//! see [`align_shift`] and [`add_conv_inner`].

mod fixed;
pub use fixed::*;

/// Quantization parameter of a tensor: number of fractional bits of the
/// Q7-style fixed-point format (`x_f ≈ x_i / 2^frac_bits`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QParam {
    pub frac_bits: i32,
}

impl QParam {
    pub fn new(frac_bits: i32) -> Self {
        Self { frac_bits }
    }

    /// The scale factor `2^frac_bits` as f32 (may be fractional for
    /// negative `frac_bits`, i.e. tensors with magnitudes above 128).
    pub fn scale(&self) -> f32 {
        (self.frac_bits as f32).exp2()
    }

    /// The paper's `dec` (integer bits): `dec = 7 - frac_bits`.
    pub fn dec(&self) -> i32 {
        7 - self.frac_bits
    }
}

/// Eq. 4: fractional bits for a tensor whose max magnitude is `max_abs`.
///
/// `dec = ceil(log2(max_abs))`, `frac_bits = 7 - dec`. A zero tensor gets
/// the finest representable scale (frac_bits = 7).
pub fn frac_bits_for(max_abs: f32) -> i32 {
    if !(max_abs > 0.0) {
        return 7;
    }
    let dec = max_abs.log2().ceil() as i32;
    7 - dec
}

/// Saturate an i32 accumulator to i8 (CMSIS `__SSAT(x, 8)`).
#[inline(always)]
pub fn sat_i8(x: i32) -> i8 {
    x.clamp(-128, 127) as i8
}

/// Arithmetic right shift that also accepts negative `shift` (left shift),
/// which occurs when the output format is finer than the accumulator's.
/// Matches the paper's Alg. 1 (plain truncating shift, no rounding).
#[inline(always)]
pub fn requantize(acc: i32, shift: i32) -> i32 {
    if shift >= 0 {
        // i32 >> is an arithmetic shift in Rust.
        acc >> shift.min(31)
    } else {
        acc << (-shift).min(31)
    }
}

/// Quantize a single value at a given parameter (Eq. 4's floor).
#[inline]
pub fn quantize_one(x: f32, q: QParam) -> i8 {
    sat_i8((x * q.scale()).floor() as i32)
}

/// Dequantize a single value.
#[inline]
pub fn dequantize_one(x: i8, q: QParam) -> f32 {
    x as f32 / q.scale()
}

/// Quantize a tensor with the Eq. 4 calibration (max-abs over the tensor).
/// Returns the int8 data and the chosen parameter.
pub fn quantize_tensor(xs: &[f32]) -> (Vec<i8>, QParam) {
    let max_abs = xs.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    let q = QParam::new(frac_bits_for(max_abs));
    (xs.iter().map(|&x| quantize_one(x, q)).collect(), q)
}

/// Quantize a tensor at a caller-chosen parameter (used when the
/// deployment pipeline fixes activations' formats from calibration data).
pub fn quantize_tensor_with(xs: &[f32], q: QParam) -> Vec<i8> {
    xs.iter().map(|&x| quantize_one(x, q)).collect()
}

/// Dequantize a tensor.
pub fn dequantize_tensor(xs: &[i8], q: QParam) -> Vec<f32> {
    xs.iter().map(|&x| dequantize_one(x, q)).collect()
}

/// Quantize an f32 bias directly at accumulator scale
/// (`frac_in + frac_w` fractional bits, i32 storage — the CMSIS-NN
/// convention of adding bias before the output shift).
pub fn quantize_bias(bias: &[f32], frac_in: i32, frac_w: i32) -> Vec<i32> {
    let scale = ((frac_in + frac_w) as f32).exp2();
    bias.iter().map(|&b| (b * scale).round() as i32).collect()
}

/// Alignment shift for add-convolution (Alg. 1 right): the operand with
/// fewer fractional bits is left-shifted by `|frac_in − frac_w|` so both
/// sit at `max(frac_in, frac_w)` fractional bits.
#[inline(always)]
pub fn align_shift(frac_in: i32, frac_w: i32) -> (i32, bool) {
    // (shift, shift_applies_to_input)
    if frac_w > frac_in {
        (frac_w - frac_in, true)
    } else {
        (frac_in - frac_w, false)
    }
}

/// Inner loop of add-convolution (Alg. 1 right, our un-garbled form):
/// contribution of one (input, weight) pair to the (negative) accumulator,
/// with operands aligned to the common exponent.
#[inline(always)]
pub fn add_conv_inner(x: i32, w: i32, shift: i32, shift_input: bool) -> i32 {
    let (xa, wa) = if shift_input {
        (x << shift, w)
    } else {
        (x, w << shift)
    };
    -(xa - wa).abs()
}

/// Output shift for add-convolution: accumulator sits at
/// `max(frac_in, frac_w)` fractional bits; bring it to `frac_out`.
#[inline(always)]
pub fn add_conv_out_shift(frac_in: i32, frac_w: i32, frac_out: i32) -> i32 {
    frac_in.max(frac_w) - frac_out
}

/// Output shift for multiplicative convolution (Alg. 1 left):
/// `frac_in + frac_w − frac_out`.
#[inline(always)]
pub fn conv_out_shift(frac_in: i32, frac_w: i32, frac_out: i32) -> i32 {
    frac_in + frac_w - frac_out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, ensure};

    #[test]
    fn frac_bits_examples() {
        // max|X| = 1.0 → dec = 0 → 7 fractional bits (classic Q7).
        assert_eq!(frac_bits_for(1.0), 7);
        // max|X| = 2.0 → dec = 1 → 6 fractional bits.
        assert_eq!(frac_bits_for(2.0), 6);
        // max|X| = 0.5 → dec = -1 → 8 fractional bits.
        assert_eq!(frac_bits_for(0.5), 8);
        // max|X| = 100 → dec = 7 → 0 fractional bits.
        assert_eq!(frac_bits_for(100.0), 0);
        // degenerate all-zero tensor
        assert_eq!(frac_bits_for(0.0), 7);
    }

    #[test]
    fn eq4_uses_floor_not_round() {
        let q = QParam::new(7);
        // 0.999 * 128 = 127.87 → floor → 127
        assert_eq!(quantize_one(0.999, q), 127);
        // -0.999 * 128 = -127.87 → floor → -128
        assert_eq!(quantize_one(-0.999, q), -128);
    }

    #[test]
    fn saturation() {
        let q = QParam::new(7);
        assert_eq!(quantize_one(4.0, q), 127);
        assert_eq!(quantize_one(-4.0, q), -128);
        assert_eq!(sat_i8(1 << 20), 127);
        assert_eq!(sat_i8(-(1 << 20)), -128);
    }

    #[test]
    fn requantize_both_directions() {
        assert_eq!(requantize(256, 4), 16);
        assert_eq!(requantize(-256, 4), -16);
        assert_eq!(requantize(3, -2), 12);
        // truncating arithmetic shift (rounds toward -inf)
        assert_eq!(requantize(-1, 1), -1);
    }

    #[test]
    fn quantize_tensor_range_fits_i8() {
        let xs = [3.2f32, -1.5, 0.25, 2.9];
        let (qs, p) = quantize_tensor(&xs);
        // max 3.2 → dec=2 → frac_bits=5 → scale 32
        assert_eq!(p.frac_bits, 5);
        assert_eq!(qs[0], (3.2f32 * 32.0).floor() as i8);
    }

    #[test]
    fn roundtrip_error_bounded_by_scale() {
        check(
            "quant-roundtrip",
            128,
            |rng, _| {
                let n = rng.range(1, 64);
                (0..n).map(|_| rng.f32_range(-4.0, 4.0)).collect::<Vec<f32>>()
            },
            |xs| {
                let (qs, p) = quantize_tensor(xs);
                let step = 1.0 / p.scale();
                for (x, q) in xs.iter().zip(&qs) {
                    let back = dequantize_one(*q, p);
                    // floor quantization: error in [0, step) unless saturated
                    let err = (x - back).abs();
                    ensure(
                        err <= step + 1e-6,
                        format!("err {err} > step {step} for {x} -> {q}"),
                    )?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn conv_shift_identity() {
        // Requantizing a product through conv_out_shift reproduces the
        // float product within one output step.
        check(
            "conv-shift",
            256,
            |rng, _| {
                (
                    rng.f32_range(-1.0, 1.0),
                    rng.f32_range(-1.0, 1.0),
                )
            },
            |&(xf, wf)| {
                let qi = QParam::new(7);
                let qw = QParam::new(7);
                let qo = QParam::new(5);
                let x = quantize_one(xf, qi) as i32;
                let w = quantize_one(wf, qw) as i32;
                let shift = conv_out_shift(qi.frac_bits, qw.frac_bits, qo.frac_bits);
                let out = requantize(x * w, shift);
                let approx = dequantize_one(sat_i8(out), qo);
                ensure(
                    (approx - xf * wf).abs() <= 3.0 / qo.scale(),
                    format!("{approx} vs {}", xf * wf),
                )
            },
        );
    }

    #[test]
    fn add_conv_alignment_is_exact() {
        // After alignment, |x - w| computed in integers equals the fixed
        // point value of |x_f - w_f| at the common exponent (up to the
        // original quantization error).
        let fi = 5;
        let fw = 7;
        let (shift, on_input) = align_shift(fi, fw);
        assert_eq!((shift, on_input), (2, true));
        let x = 10i32; // 10/32 = 0.3125
        let w = 50i32; // 50/128 = 0.390625
        let contrib = add_conv_inner(x, w, shift, on_input);
        // aligned x = 40 (=0.3125 at 2^-7), |40-50| = 10 → -10/128
        assert_eq!(contrib, -10);
    }

    #[test]
    fn add_conv_inner_always_non_positive() {
        check(
            "addconv-negative",
            256,
            |rng, _| {
                (
                    rng.i8(),
                    rng.i8(),
                    rng.range(0, 3) as i32,
                    rng.below(2) == 0,
                )
            },
            |&(x, w, shift, on_input)| {
                let v = add_conv_inner(x as i32, w as i32, shift, on_input);
                ensure(v <= 0, format!("positive contribution {v}"))
            },
        );
    }

    #[test]
    fn bias_at_accumulator_scale() {
        let b = quantize_bias(&[0.5, -0.25], 7, 7);
        assert_eq!(b, vec![(0.5 * 16384.0) as i32, (-0.25 * 16384.0) as i32]);
    }

    #[test]
    fn dec_frac_duality() {
        for fb in -3..=10 {
            let q = QParam::new(fb);
            assert_eq!(q.dec(), 7 - fb);
        }
    }
}
