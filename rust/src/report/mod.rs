//! Report emitters: CSV and markdown renderings of the harness outputs,
//! in the same rows/series layout as the paper's figures and tables.
//! Used by the `convbench` CLI, the benches and EXPERIMENTS.md.

use std::fmt::Write as _;

use crate::analytic::Primitive;
use crate::harness::{FreqPoint, SweepPoint, Table1Row, Table3Row, Table4Row};
use crate::mcu::OptLevel;

/// CSV for a Fig. 2-style sweep: one row per (experiment, primitive,
/// axis value) with theory + both measurements.
pub fn sweep_csv(points: &[SweepPoint]) -> String {
    let mut s = String::from(
        "experiment,primitive,axis_value,params,theoretical_macs,\
         latency_scalar_s,energy_scalar_mj,mem_scalar,\
         latency_simd_s,energy_simd_mj,mem_simd,speedup,mem_ratio\n",
    );
    for p in points {
        let (ls, es, mm, sp, mr) = match p.simd {
            Some(v) => (
                format!("{:.6e}", v.latency_s),
                format!("{:.6e}", v.energy_mj),
                format!("{}", v.mem_accesses),
                format!("{:.3}", p.speedup().unwrap()),
                format!("{:.3}", p.mem_access_ratio().unwrap()),
            ),
            None => (String::new(), String::new(), String::new(), String::new(), String::new()),
        };
        let _ = writeln!(
            s,
            "{},{},{},{},{},{:.6e},{:.6e},{},{},{},{},{},{}",
            p.experiment,
            p.primitive.name(),
            p.axis_value,
            p.theory.params,
            p.theory.macs,
            p.scalar.latency_s,
            p.scalar.energy_mj,
            p.scalar.mem_accesses,
            ls,
            es,
            mm,
            sp,
            mr
        );
    }
    s
}

/// Markdown series table for one experiment / one metric — the textual
/// equivalent of a Fig. 2 panel: rows = axis values, columns = primitives.
pub fn figure_panel_markdown(
    points: &[SweepPoint],
    experiment: usize,
    axis_name: &str,
    metric_name: &str,
    metric: impl Fn(&SweepPoint) -> Option<f64>,
) -> String {
    let pts: Vec<&SweepPoint> = points.iter().filter(|p| p.experiment == experiment).collect();
    let mut values: Vec<usize> = pts.iter().map(|p| p.axis_value).collect();
    values.sort_unstable();
    values.dedup();

    let mut s = format!("**Experiment {experiment}** — {metric_name} vs {axis_name}\n\n");
    let _ = write!(s, "| {axis_name} |");
    for prim in Primitive::ALL {
        let _ = write!(s, " {} |", prim.name());
    }
    s.push('\n');
    let _ = write!(s, "|---|");
    for _ in Primitive::ALL {
        let _ = write!(s, "---|");
    }
    s.push('\n');
    for v in values {
        let _ = write!(s, "| {v} |");
        for prim in Primitive::ALL {
            let cell = pts
                .iter()
                .find(|p| p.axis_value == v && p.primitive == prim)
                .and_then(|p| metric(p));
            match cell {
                Some(x) => {
                    let _ = write!(s, " {x:.4e} |");
                }
                None => {
                    let _ = write!(s, " — |");
                }
            }
        }
        s.push('\n');
    }
    s
}

/// Markdown for Table 1.
pub fn table1_markdown(rows: &[Table1Row]) -> String {
    let mut s = String::from(
        "| Convolution type | Parameters | Theoretical MACs | Parameters gain | Complexity gain |\n\
         |---|---|---|---|---|\n",
    );
    for r in rows {
        let _ = writeln!(
            s,
            "| {} | {} | {} | {:.4} | {:.4} |",
            r.primitive.name(),
            r.params,
            r.macs,
            r.param_gain,
            r.complexity_gain
        );
    }
    s
}

/// Markdown for Table 3 (average power vs frequency).
pub fn table3_markdown(rows: &[Table3Row]) -> String {
    let mut head = String::from("| |");
    let mut sep = String::from("|---|");
    let mut no_simd = String::from("| No SIMD |");
    let mut simd = String::from("| SIMD |");
    for r in rows {
        let _ = write!(head, " {} MHz |", r.freq_mhz);
        sep.push_str("---|");
        let _ = write!(no_simd, " {:.2} |", r.no_simd_mw);
        let _ = write!(simd, " {:.2} |", r.simd_mw);
    }
    format!("{head}\n{sep}\n{no_simd}\n{simd}\n")
}

/// Markdown for Table 4 (optimization level effect).
pub fn table4_markdown(rows: &[Table4Row]) -> String {
    let mut s = String::from(
        "| | Opt level | Latency (s) | Consumption (mJ) | Optimization speedup | SIMD speedup |\n\
         |---|---|---|---|---|---|\n",
    );
    for r in rows {
        let opt = match r.opt {
            OptLevel::O0 => "O0",
            OptLevel::Os => "Os",
        };
        let _ = writeln!(
            s,
            "| {} | {} | {:.3} | {:.1} | {} | {} |",
            if r.simd { "SIMD" } else { "No SIMD" },
            opt,
            r.latency_s,
            r.energy_mj,
            r.opt_speedup.map(|x| format!("{x:.2}")).unwrap_or_else(|| "—".into()),
            r.simd_speedup.map(|x| format!("{x:.2}")).unwrap_or_else(|| "—".into()),
        );
    }
    s
}

/// CSV for the Fig. 4 frequency sweep.
pub fn fig4_csv(points: &[FreqPoint]) -> String {
    let mut s = String::from(
        "freq_mhz,latency_scalar_s,energy_scalar_mj,power_scalar_mw,\
         latency_simd_s,energy_simd_mj,power_simd_mw\n",
    );
    for p in points {
        let _ = writeln!(
            s,
            "{},{:.6e},{:.6e},{:.3},{:.6e},{:.6e},{:.3}",
            p.freq_mhz,
            p.scalar.latency_s,
            p.scalar.energy_mj,
            p.scalar.power_mw,
            p.simd.latency_s,
            p.simd.energy_mj,
            p.simd.power_mw
        );
    }
    s
}

/// Machine-readable summary of a tuned-vs-fixed comparison (consumed by
/// dashboards / CI trend tracking; the human-readable table is
/// [`crate::harness::tuned_markdown`]).
pub fn tuned_summary_json(rows: &[crate::harness::TunedCmpRow]) -> String {
    use crate::util::json::Json;
    let workloads: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj()
                .field("experiment", r.experiment)
                .field("primitive", r.primitive.name())
                .field("fixed_scalar_latency_s", r.fixed_scalar.latency_s)
                .field(
                    "fixed_simd_latency_s",
                    r.fixed_simd.map(|m| Json::Num(m.latency_s)).unwrap_or(Json::Null),
                )
                .field("tuned_latency_s", r.tuned_latency.latency_s)
                .field("best_fixed_energy_mj", r.best_fixed_energy_mj())
                .field("tuned_energy_mj", r.tuned_energy.energy_mj)
                .field("tuned_peak_ram_bytes", r.tuned_latency.peak_ram_bytes)
                .field("evaluations", r.stats.evaluations)
                .field("analytic_scored", r.stats.analytic)
                .field("cache_hits", r.stats.cache_hits)
                .field("never_worse", r.tuned_is_never_worse())
        })
        .collect();
    Json::obj()
        .field("workloads", Json::Arr(workloads))
        .field("all_never_worse", rows.iter().all(|r| r.tuned_is_never_worse()))
        .to_string()
}

/// Machine-readable dump of the serving engine's final
/// [`crate::coordinator::ServerStats`] — shed/error counters, the
/// latency split and the batch-size histogram. Emitted by
/// `convbench serve` on shutdown next to the trace/metrics artifacts.
pub fn server_stats_json(stats: &crate::coordinator::ServerStats) -> String {
    use crate::util::json::Json;
    let hist: Vec<Json> = stats.batch_hist.iter().map(|&c| Json::Num(c as f64)).collect();
    let mut backends = Json::obj();
    for (model, summary) in &stats.backends {
        backends = backends.field(model, summary.as_str());
    }
    Json::obj()
        .field("served", stats.served)
        .field("errors", stats.errors)
        .field("shed", stats.shed)
        .field("p50_us", stats.p50_us)
        .field("p99_us", stats.p99_us)
        .field("mean_us", stats.mean_us)
        .field("queue_p50_us", stats.queue_p50_us)
        .field("queue_p99_us", stats.queue_p99_us)
        .field("queue_mean_us", stats.queue_mean_us)
        .field("exec_p50_us", stats.exec_p50_us)
        .field("exec_p99_us", stats.exec_p99_us)
        .field("exec_mean_us", stats.exec_mean_us)
        .field("batch_hist", Json::Arr(hist))
        .field("worker_panics", stats.worker_panics)
        .field("respawns", stats.respawns)
        .field("quarantined", stats.quarantined)
        .field("breaker_trips", stats.breaker_trips)
        .field("degraded_batches", stats.degraded_batches)
        .field("backends", backends)
        .to_string()
}

/// Write a string to a file, creating parent directories.
pub fn write_report(path: &str, content: &str) -> std::io::Result<()> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, content)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::{quick_plans, run_all, table1_costs, table3_power, table4_optlevel};
    use crate::mcu::McuConfig;
    use crate::models::LayerParams;

    #[test]
    fn sweep_csv_has_header_and_rows() {
        let pts = run_all(&quick_plans()[..1], &McuConfig::default());
        let csv = sweep_csv(&pts);
        let lines: Vec<&str> = csv.lines().collect();
        assert!(lines[0].starts_with("experiment,primitive"));
        assert_eq!(lines.len(), pts.len() + 1);
        // add rows end with empty simd fields
        assert!(csv.contains("add"));
    }

    #[test]
    fn panel_markdown_is_well_formed() {
        let pts = run_all(&quick_plans()[..1], &McuConfig::default());
        let md = figure_panel_markdown(&pts, 1, "groups", "latency (scalar)", |p| {
            Some(p.scalar.latency_s)
        });
        assert!(md.contains("| groups |"));
        assert!(md.contains("standard"));
        // add column renders its SIMD-only metrics as —
        let md2 = figure_panel_markdown(&pts, 1, "groups", "speedup", |p| p.speedup());
        assert!(md2.contains("—"));
    }

    #[test]
    fn table_markdowns_render() {
        let t1 = table1_markdown(&table1_costs(&LayerParams::new(2, 3, 32, 16, 16)));
        assert_eq!(t1.lines().count(), 7);
        let t3 = table3_markdown(&table3_power());
        assert!(t3.contains("No SIMD"));
        let t4 = table4_markdown(&table4_optlevel());
        assert!(t4.contains("Os"));
        assert!(t4.contains("SIMD"));
    }

    #[test]
    fn fig4_csv_rows() {
        use crate::harness::fig4_frequency_sweep;
        let pts = fig4_frequency_sweep(&[10.0, 80.0]);
        let csv = fig4_csv(&pts);
        assert_eq!(csv.lines().count(), 3);
    }

    #[test]
    fn server_stats_json_parses_back() {
        use crate::coordinator::ServerStats;
        use crate::util::json::Json;
        let stats = ServerStats {
            served: 12,
            errors: 1,
            shed: 2,
            p50_us: 410.0,
            p99_us: 900.0,
            mean_us: 450.5,
            queue_p50_us: 100.0,
            queue_p99_us: 220.0,
            queue_mean_us: 120.0,
            exec_p50_us: 300.0,
            exec_p99_us: 700.0,
            exec_mean_us: 330.5,
            batch_hist: vec![4, 2, 0, 1],
            worker_panics: 3,
            respawns: 3,
            quarantined: 1,
            breaker_trips: 2,
            degraded_batches: 5,
            backends: vec![
                ("mcunet-std".to_string(), "scalar".to_string()),
                ("mcunet-dws".to_string(), "vec:7/9".to_string()),
            ],
        };
        let j = Json::parse(&server_stats_json(&stats)).expect("valid json");
        assert_eq!(j.get("served").and_then(|v| v.as_i64()), Some(12));
        assert_eq!(j.get("errors").and_then(|v| v.as_i64()), Some(1));
        assert_eq!(j.get("shed").and_then(|v| v.as_i64()), Some(2));
        let hist = j.get("batch_hist").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(hist.len(), 4);
        assert_eq!(hist[0].as_i64(), Some(4));
        assert!((j.get("mean_us").unwrap().as_f64().unwrap() - 450.5).abs() < 1e-9);
        // the fault-tolerance counters survive the round trip
        assert_eq!(j.get("worker_panics").and_then(|v| v.as_i64()), Some(3));
        assert_eq!(j.get("respawns").and_then(|v| v.as_i64()), Some(3));
        assert_eq!(j.get("quarantined").and_then(|v| v.as_i64()), Some(1));
        assert_eq!(j.get("breaker_trips").and_then(|v| v.as_i64()), Some(2));
        assert_eq!(j.get("degraded_batches").and_then(|v| v.as_i64()), Some(5));
        // the per-model deployed-backend summary survives the round trip
        let backends = j.get("backends").unwrap();
        assert_eq!(backends.get("mcunet-std").and_then(|v| v.as_str()), Some("scalar"));
        assert_eq!(backends.get("mcunet-dws").and_then(|v| v.as_str()), Some("vec:7/9"));
    }

    #[test]
    fn tuned_summary_json_parses_back() {
        use crate::harness::tuned_vs_fixed;
        use crate::tuner::TuningCache;
        use crate::util::json::Json;
        let mut cache = TuningCache::in_memory();
        let rows = tuned_vs_fixed(&quick_plans()[..1], &McuConfig::default(), &mut cache);
        let text = tuned_summary_json(&rows);
        let j = Json::parse(&text).expect("valid json");
        assert_eq!(j.get("all_never_worse").and_then(|v| v.as_bool()), Some(true));
        let w = j.get("workloads").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(w.len(), rows.len());
        // the add row has a null fixed SIMD latency
        assert!(w
            .iter()
            .any(|v| v.get("fixed_simd_latency_s") == Some(&Json::Null)));
    }
}
