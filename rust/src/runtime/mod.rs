//! PJRT runtime: loads the HLO-text artifacts produced by the build-time
//! python path (`python/compile/aot.py` — JAX/Pallas lowered to HLO text,
//! see /opt/xla-example/load_hlo and aot_recipe) and executes them on the
//! `xla` crate's PJRT CPU client. Python never runs here — the rust binary
//! is self-contained once `artifacts/` exists.
//!
//! The cross-layer contract: every artifact takes `i32` tensors holding
//! int8-quantized values (i32 at the interface dodges dtype-conversion
//! pitfalls between jax and xla_extension 0.5.1; the arithmetic inside is
//! exact integer math) and returns `i32` tensors, so the rust engine's
//! outputs can be compared bit-for-bit.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

/// A PJRT execution context (CPU).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO **text** artifact and compile it.
    pub fn load_hlo_text(&self, path: impl AsRef<Path>) -> Result<LoadedModel> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .map_err(|e| anyhow!("parse HLO text {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {path:?}: {e:?}"))?;
        Ok(LoadedModel {
            name: path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
            exe,
        })
    }
}

/// A compiled executable plus metadata.
pub struct LoadedModel {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

/// An input tensor for execution: flat i32 data + dims.
#[derive(Clone, Debug)]
pub struct InputI32 {
    pub data: Vec<i32>,
    pub dims: Vec<i64>,
}

impl InputI32 {
    pub fn new(data: Vec<i32>, dims: &[usize]) -> Self {
        let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
        assert_eq!(
            data.len() as i64,
            d.iter().product::<i64>(),
            "input volume mismatch"
        );
        Self { data, dims: d }
    }

    /// From int8 engine data.
    pub fn from_i8(data: &[i8], dims: &[usize]) -> Self {
        Self::new(data.iter().map(|&v| v as i32).collect(), dims)
    }

    fn literal(&self) -> Result<xla::Literal> {
        xla::Literal::vec1(&self.data)
            .reshape(&self.dims)
            .map_err(|e| anyhow!("reshape input: {e:?}"))
    }
}

impl LoadedModel {
    /// Execute with i32 inputs; returns each tuple element flattened.
    /// The artifacts are lowered with `return_tuple=True`, so the single
    /// output literal is a tuple.
    pub fn run_i32(&self, inputs: &[InputI32]) -> Result<Vec<Vec<i32>>> {
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|i| i.literal()).collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow!("untuple: {e:?}"))?;
        parts
            .into_iter()
            .map(|l| l.to_vec::<i32>().map_err(|e| anyhow!("to_vec: {e:?}")))
            .collect()
    }

    /// Execute and saturate outputs back to the engine's i8 domain.
    pub fn run_to_i8(&self, inputs: &[InputI32]) -> Result<Vec<Vec<i8>>> {
        Ok(self
            .run_i32(inputs)?
            .into_iter()
            .map(|v| v.into_iter().map(crate::quant::sat_i8).collect())
            .collect())
    }
}

/// Resolve an artifact path: `<dir>/<name>.hlo.txt`.
pub fn artifact_path(dir: &str, name: &str) -> String {
    format!("{dir}/{name}.hlo.txt")
}

/// List available artifacts in a directory.
pub fn list_artifacts(dir: &str) -> Vec<String> {
    let mut names: Vec<String> = std::fs::read_dir(dir)
        .map(|rd| {
            rd.filter_map(|e| e.ok())
                .filter_map(|e| {
                    let f = e.file_name().to_string_lossy().into_owned();
                    f.strip_suffix(".hlo.txt").map(|s| s.to_string())
                })
                .collect()
        })
        .unwrap_or_default();
    names.sort();
    names
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn input_volume_checked() {
        let i = InputI32::new(vec![1, 2, 3, 4], &[2, 2]);
        assert_eq!(i.dims, vec![2, 2]);
    }

    #[test]
    #[should_panic(expected = "input volume mismatch")]
    fn bad_volume_panics() {
        InputI32::new(vec![1, 2, 3], &[2, 2]);
    }

    #[test]
    fn from_i8_sign_extends() {
        let i = InputI32::from_i8(&[-128, 127], &[2]);
        assert_eq!(i.data, vec![-128, 127]);
    }

    #[test]
    fn artifact_paths() {
        assert_eq!(artifact_path("artifacts", "model"), "artifacts/model.hlo.txt");
        assert!(list_artifacts("/nonexistent-dir").is_empty());
    }
}
