//! PJRT runtime: loads the HLO-text artifacts produced by the build-time
//! python path (`python/compile/aot.py` — JAX/Pallas lowered to HLO text)
//! and executes them on the `xla` crate's PJRT CPU client. Python never
//! runs here — the rust binary is self-contained once `artifacts/` exists.
//!
//! The PJRT client itself lives in [`pjrt`] behind the `pjrt` cargo
//! feature: the `xla` crate is not part of the offline vendor set, so the
//! default build ships only the artifact bookkeeping (paths, listings,
//! the [`InputI32`] interchange type) and the engine-side halves of the
//! cross-layer contract. Enable `--features pjrt` in an environment with
//! the `xla` crate vendored (see README "PJRT validation").
//!
//! The cross-layer contract: every artifact takes `i32` tensors holding
//! int8-quantized values (i32 at the interface dodges dtype-conversion
//! pitfalls between jax and xla_extension 0.5.1; the arithmetic inside is
//! exact integer math) and returns `i32` tensors, so the rust engine's
//! outputs can be compared bit-for-bit.

#[cfg(feature = "pjrt")]
pub mod pjrt;

#[cfg(feature = "pjrt")]
pub use pjrt::{LoadedModel, Runtime};

/// An input tensor for artifact execution: flat i32 data + dims.
#[derive(Clone, Debug)]
pub struct InputI32 {
    pub data: Vec<i32>,
    pub dims: Vec<i64>,
}

impl InputI32 {
    pub fn new(data: Vec<i32>, dims: &[usize]) -> Self {
        let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
        assert_eq!(
            data.len() as i64,
            d.iter().product::<i64>(),
            "input volume mismatch"
        );
        Self { data, dims: d }
    }

    /// From int8 engine data.
    pub fn from_i8(data: &[i8], dims: &[usize]) -> Self {
        Self::new(data.iter().map(|&v| v as i32).collect(), dims)
    }
}

/// Resolve an artifact path: `<dir>/<name>.hlo.txt`.
pub fn artifact_path(dir: &str, name: &str) -> String {
    format!("{dir}/{name}.hlo.txt")
}

/// List available artifacts in a directory.
pub fn list_artifacts(dir: &str) -> Vec<String> {
    let mut names: Vec<String> = std::fs::read_dir(dir)
        .map(|rd| {
            rd.filter_map(|e| e.ok())
                .filter_map(|e| {
                    let f = e.file_name().to_string_lossy().into_owned();
                    f.strip_suffix(".hlo.txt").map(|s| s.to_string())
                })
                .collect()
        })
        .unwrap_or_default();
    names.sort();
    names
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn input_volume_checked() {
        let i = InputI32::new(vec![1, 2, 3, 4], &[2, 2]);
        assert_eq!(i.dims, vec![2, 2]);
    }

    #[test]
    #[should_panic(expected = "input volume mismatch")]
    fn bad_volume_panics() {
        InputI32::new(vec![1, 2, 3], &[2, 2]);
    }

    #[test]
    fn from_i8_sign_extends() {
        let i = InputI32::from_i8(&[-128, 127], &[2]);
        assert_eq!(i.data, vec![-128, 127]);
    }

    #[test]
    fn artifact_paths() {
        assert_eq!(artifact_path("artifacts", "model"), "artifacts/model.hlo.txt");
        assert!(list_artifacts("/nonexistent-dir").is_empty());
    }
}
