//! The PJRT client half of the runtime (feature `pjrt`): compiles HLO-text
//! artifacts with the `xla` crate and executes them on the CPU client.
//! Errors surface as `String` (the crate is dependency-free by default;
//! see `rust/src/util/`), formatted from the underlying xla errors.

use std::path::Path;

use super::InputI32;

/// A PJRT execution context (CPU).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self, String> {
        let client = xla::PjRtClient::cpu().map_err(|e| format!("pjrt cpu client: {e:?}"))?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO **text** artifact and compile it.
    pub fn load_hlo_text(&self, path: impl AsRef<Path>) -> Result<LoadedModel, String> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| "artifact path not utf-8".to_string())?,
        )
        .map_err(|e| format!("parse HLO text {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| format!("compile {path:?}: {e:?}"))?;
        Ok(LoadedModel {
            name: path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
            exe,
        })
    }
}

/// A compiled executable plus metadata.
pub struct LoadedModel {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

fn input_literal(input: &InputI32) -> Result<xla::Literal, String> {
    xla::Literal::vec1(&input.data)
        .reshape(&input.dims)
        .map_err(|e| format!("reshape input: {e:?}"))
}

impl LoadedModel {
    /// Execute with i32 inputs; returns each tuple element flattened.
    /// The artifacts are lowered with `return_tuple=True`, so the single
    /// output literal is a tuple.
    pub fn run_i32(&self, inputs: &[InputI32]) -> Result<Vec<Vec<i32>>, String> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(input_literal)
            .collect::<Result<_, String>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| format!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| format!("to_literal: {e:?}"))?;
        let parts = result.to_tuple().map_err(|e| format!("untuple: {e:?}"))?;
        parts
            .into_iter()
            .map(|l| l.to_vec::<i32>().map_err(|e| format!("to_vec: {e:?}")))
            .collect()
    }

    /// Execute and saturate outputs back to the engine's i8 domain.
    pub fn run_to_i8(&self, inputs: &[InputI32]) -> Result<Vec<Vec<i8>>, String> {
        Ok(self
            .run_i32(inputs)?
            .into_iter()
            .map(|v| v.into_iter().map(crate::quant::sat_i8).collect())
            .collect())
    }
}
