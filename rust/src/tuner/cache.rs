//! Persistent tuning cache: maps (layer signature, `McuConfig`
//! fingerprint, objective) to the winning schedule-space candidate and
//! its simulated measurement, serialized as JSON via [`crate::util::json`]
//! so repeated deployments skip the simulator entirely (a warm `tune` run
//! performs zero evaluations — asserted by the integration tests).
//!
//! Invalidation is by construction: the key embeds the MCU configuration
//! and the objective, so changing either (different frequency, `-O0`
//! instead of `-Os`, energy instead of latency) misses cleanly and
//! re-tunes, while the stale entries stay valid for their own
//! configuration.

use std::collections::BTreeMap;

use crate::mcu::McuConfig;
use crate::util::json::Json;

use super::pareto::Frontier;
use super::space::{Candidate, KernelImpl, Lowering};
use super::BackendSel;
use crate::nn::Backend;

/// Cache file format version (bump on incompatible schema changes —
/// mismatching files are discarded wholesale). v5: entries and frontier
/// points gained a required `flash_bytes` field (deployed weight bytes
/// of the winning candidate, post-compaction for pruned graphs) feeding
/// the flash term of the tuner objective — v4 files carry no flash
/// column and are discarded. v4: files gained a
/// `frontiers` map (whole-graph Pareto frontiers keyed by graph
/// signature × MCU × objective × backend policy) and per-entry
/// `ram_bytes` semantics stayed node-local while schedule-level RAM
/// reporting moved to the liveness model — v3 files could replay
/// alongside stale liveness-free frontiers, so they are discarded. v3:
/// entries gained a required `backend` field (host execution backend of
/// the winning candidate) and keys gained a backend-policy segment, so
/// a schedule tuned under one `--backend` policy can never be replayed
/// under another; v2 files predate the backend axis and are discarded.
/// v2: keys switched from per-layer to per-node signatures, which fold
/// the node's input topology (`~in<d1[,d2]>` producer-distance suffix)
/// so graph rewiring invalidates by construction; v1 files hold
/// orphaned keys and are discarded.
pub const CACHE_VERSION: i64 = 5;

/// A cached per-layer decision: the winning candidate plus its simulated
/// measurement (all inputs to the objective, so replay needs no simulator).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CacheEntry {
    pub candidate: Candidate,
    pub cycles: f64,
    pub latency_s: f64,
    pub energy_mj: f64,
    pub mem_accesses: u64,
    pub effective_macs: u64,
    pub ram_bytes: usize,
    /// Deployed weight bytes of the winning kernel (flash footprint,
    /// post-compaction for pruned graphs).
    pub flash_bytes: usize,
}

/// Fingerprint of the simulated MCU configuration a measurement is valid
/// for (part of every cache key).
pub fn mcu_fingerprint(cfg: &McuConfig) -> String {
    format!("{:.3}MHz-{:?}", cfg.freq_mhz, cfg.opt)
}

/// Compose a cache key under the default (scalar-only) backend policy —
/// the key every legacy entry point composes.
pub fn cache_key(layer_sig: &str, mcu_fp: &str, objective: &str) -> String {
    cache_key_backend(layer_sig, mcu_fp, objective, BackendSel::Scalar)
}

/// Compose a cache key under an explicit backend policy. The policy is
/// its own key segment: a decision tuned under `--backend vec` must
/// never be replayed for a `--backend scalar` deployment (the cached
/// candidate could name a backend the policy forbids), so the two miss
/// each other by construction.
pub fn cache_key_backend(
    layer_sig: &str,
    mcu_fp: &str,
    objective: &str,
    backend: BackendSel,
) -> String {
    format!("{layer_sig}|{mcu_fp}|{objective}|{}", backend.as_str())
}

/// Compose the cache key of a whole-graph Pareto frontier: a `frontier|`
/// namespace plus graph signature ([`crate::tuner::space::graph_signature`]),
/// MCU fingerprint, objective name and backend policy — the full
/// validity domain of a frontier's measurements and schedules.
pub fn frontier_key(
    graph_sig: &str,
    mcu_fp: &str,
    objective: &str,
    backend: BackendSel,
) -> String {
    format!("frontier|{graph_sig}|{mcu_fp}|{objective}|{}", backend.as_str())
}

/// The tuning cache: an in-memory map with optional JSON persistence.
#[derive(Debug)]
pub struct TuningCache {
    path: Option<String>,
    entries: BTreeMap<String, CacheEntry>,
    frontiers: BTreeMap<String, Frontier>,
    dirty: bool,
}

impl TuningCache {
    /// A cache that lives only for this process.
    pub fn in_memory() -> Self {
        Self {
            path: None,
            entries: BTreeMap::new(),
            frontiers: BTreeMap::new(),
            dirty: false,
        }
    }

    /// Load a cache file; a missing, unreadable or incompatible file
    /// yields an empty cache bound to the same path (it will be created
    /// on [`TuningCache::save`]).
    pub fn load(path: &str) -> Self {
        let (entries, frontiers) = std::fs::read_to_string(path)
            .ok()
            .and_then(|text| Json::parse(&text).ok())
            .and_then(|json| parse_file(&json))
            .unwrap_or_default();
        Self {
            path: Some(path.to_string()),
            entries,
            frontiers,
            dirty: false,
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Whether entries were added since load/save.
    pub fn is_dirty(&self) -> bool {
        self.dirty
    }

    pub fn get(&self, key: &str) -> Option<&CacheEntry> {
        self.entries.get(key)
    }

    pub fn put(&mut self, key: String, entry: CacheEntry) {
        let prev = self.entries.insert(key, entry);
        if prev != Some(entry) {
            self.dirty = true;
        }
    }

    /// Cached whole-graph frontiers ([`frontier_key`] keys).
    pub fn get_frontier(&self, key: &str) -> Option<&Frontier> {
        self.frontiers.get(key)
    }

    pub fn put_frontier(&mut self, key: String, frontier: Frontier) {
        let changed = self.frontiers.get(&key) != Some(&frontier);
        if changed {
            self.frontiers.insert(key, frontier);
            self.dirty = true;
        }
    }

    /// Number of cached frontiers (per-node entries are [`TuningCache::len`]).
    pub fn frontier_len(&self) -> usize {
        self.frontiers.len()
    }

    /// Serialize the whole cache.
    pub fn to_json(&self) -> Json {
        let mut fields = Vec::with_capacity(self.entries.len());
        for (key, e) in &self.entries {
            let (patches, filters) = match e.candidate.lowering {
                Lowering::Direct => (0usize, 0usize),
                Lowering::Im2col { patches, filters } => (patches, filters),
            };
            fields.push((
                key.clone(),
                Json::obj()
                    .field("kernel", e.candidate.kernel.as_str())
                    .field("lowering", e.candidate.lowering.path_name())
                    .field("backend", e.candidate.backend.as_str())
                    .field("patches", patches)
                    .field("filters", filters)
                    .field("cycles", e.cycles)
                    .field("latency_s", e.latency_s)
                    .field("energy_mj", e.energy_mj)
                    .field("mem_accesses", e.mem_accesses)
                    .field("effective_macs", e.effective_macs)
                    .field("ram_bytes", e.ram_bytes)
                    .field("flash_bytes", e.flash_bytes),
            ));
        }
        let frontiers: Vec<(String, Json)> = self
            .frontiers
            .iter()
            .map(|(k, f)| (k.clone(), f.to_json()))
            .collect();
        Json::obj()
            .field("version", CACHE_VERSION)
            .field("entries", Json::Obj(fields))
            .field("frontiers", Json::Obj(frontiers))
    }

    /// Persist to the bound path (no-op for in-memory caches). Parent
    /// directories are created as needed.
    pub fn save(&mut self) -> std::io::Result<()> {
        let Some(path) = &self.path else { return Ok(()) };
        if let Some(parent) = std::path::Path::new(path).parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(path, self.to_json().to_string())?;
        self.dirty = false;
        Ok(())
    }
}

trait LoweringName {
    fn path_name(&self) -> &'static str;
}

impl LoweringName for Lowering {
    fn path_name(&self) -> &'static str {
        match self {
            Lowering::Direct => "direct",
            Lowering::Im2col { .. } => "im2col",
        }
    }
}

type ParsedFile = (BTreeMap<String, CacheEntry>, BTreeMap<String, Frontier>);

fn parse_file(json: &Json) -> Option<ParsedFile> {
    if json.get("version")?.as_i64()? != CACHE_VERSION {
        return None;
    }
    let entries = parse_entry_map(json.get("entries")?)?;
    let mut frontiers = BTreeMap::new();
    // tolerate a missing map (hand-trimmed files); reject malformed ones
    if let Some(fj) = json.get("frontiers") {
        for (key, v) in fj.as_obj()? {
            frontiers.insert(key.clone(), Frontier::from_json(v)?);
        }
    }
    Some((entries, frontiers))
}

#[cfg(test)]
fn parse_entries(json: &Json) -> Option<BTreeMap<String, CacheEntry>> {
    parse_file(json).map(|(e, _)| e)
}

fn parse_entry_map(entries: &Json) -> Option<BTreeMap<String, CacheEntry>> {
    let mut out = BTreeMap::new();
    for (key, v) in entries.as_obj()? {
        let kernel = KernelImpl::parse(v.get("kernel")?.as_str()?).ok()?;
        let lowering = match v.get("lowering")?.as_str()? {
            "direct" => Lowering::Direct,
            "im2col" => Lowering::Im2col {
                patches: v.get("patches")?.as_i64()? as usize,
                filters: v.get("filters")?.as_i64()? as usize,
            },
            _ => return None,
        };
        let backend = Backend::parse(v.get("backend")?.as_str()?).ok()?;
        out.insert(
            key.clone(),
            CacheEntry {
                candidate: Candidate { kernel, lowering, backend },
                cycles: v.get("cycles")?.as_f64()?,
                latency_s: v.get("latency_s")?.as_f64()?,
                energy_mj: v.get("energy_mj")?.as_f64()?,
                mem_accesses: v.get("mem_accesses")?.as_i64()? as u64,
                effective_macs: v.get("effective_macs")?.as_i64()? as u64,
                ram_bytes: v.get("ram_bytes")?.as_i64()? as usize,
                flash_bytes: v.get("flash_bytes")?.as_i64()? as usize,
            },
        );
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mcu::OptLevel;

    fn entry(lat: f64) -> CacheEntry {
        CacheEntry {
            candidate: Candidate {
                kernel: KernelImpl::AsIs,
                lowering: Lowering::Im2col { patches: 2, filters: 2 },
                backend: Backend::ScalarRef,
            },
            cycles: lat * 84e6,
            latency_s: lat,
            energy_mj: lat * 31.0,
            mem_accesses: 1234,
            effective_macs: 5678,
            ram_bytes: 4096,
            flash_bytes: 2048,
        }
    }

    #[test]
    fn roundtrip_through_json_text_is_identical() {
        let mut c = TuningCache::in_memory();
        c.put(cache_key("conv[x]@8x8x4", "84.000MHz-Os", "latency"), entry(0.011));
        c.put(
            cache_key("dw[y]@8x8x4", "84.000MHz-Os", "energy"),
            CacheEntry {
                candidate: Candidate {
                    kernel: KernelImpl::DepthwiseAsConv,
                    lowering: Lowering::Direct,
                    backend: Backend::ScalarRef,
                },
                ..entry(0.5)
            },
        );
        let text = c.to_json().to_string();
        let parsed = parse_entries(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(parsed.len(), 2);
        for (k, v) in &parsed {
            assert_eq!(c.get(k), Some(v), "{k}");
        }
    }

    #[test]
    fn file_roundtrip_and_warm_reload() {
        let dir = std::env::temp_dir().join("convbench-cache-test");
        let path = dir.join("tuning.json");
        let path = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);

        let mut c = TuningCache::load(&path);
        assert!(c.is_empty());
        let key = cache_key("conv[a]@4x4x2", &mcu_fingerprint(&McuConfig::default()), "latency");
        c.put(key.clone(), entry(0.002));
        assert!(c.is_dirty());
        c.save().expect("save cache");
        assert!(!c.is_dirty());

        let warm = TuningCache::load(&path);
        assert_eq!(warm.len(), 1);
        assert_eq!(warm.get(&key), Some(&entry(0.002)));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corrupt_or_versioned_files_load_empty() {
        let dir = std::env::temp_dir().join("convbench-cache-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("corrupt.json");
        std::fs::write(&path, "not json at all {{{").unwrap();
        assert!(TuningCache::load(path.to_str().unwrap()).is_empty());
        std::fs::write(&path, r#"{"version":999,"entries":{}}"#).unwrap();
        assert!(TuningCache::load(path.to_str().unwrap()).is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn mcu_config_change_invalidates_by_key() {
        let os = McuConfig::default();
        let o0 = McuConfig { freq_mhz: 84.0, opt: OptLevel::O0 };
        let f20 = McuConfig { freq_mhz: 20.0, opt: OptLevel::Os };
        let sig = "conv[z]@8x8x8";
        let k_os = cache_key(sig, &mcu_fingerprint(&os), "latency");
        let k_o0 = cache_key(sig, &mcu_fingerprint(&o0), "latency");
        let k_f20 = cache_key(sig, &mcu_fingerprint(&f20), "latency");
        assert_ne!(k_os, k_o0);
        assert_ne!(k_os, k_f20);
        let mut c = TuningCache::in_memory();
        c.put(k_os.clone(), entry(0.01));
        assert!(c.get(&k_os).is_some());
        assert!(c.get(&k_o0).is_none(), "O0 must miss an Os-keyed entry");
        assert!(c.get(&k_f20).is_none(), "20 MHz must miss an 84 MHz entry");
        // objective change misses too
        assert!(c.get(&cache_key(sig, &mcu_fingerprint(&os), "energy")).is_none());
    }

    #[test]
    fn backend_change_invalidates_cached_entries() {
        // Policy axis: the same (signature, MCU, objective) under a
        // different --backend policy composes a different key, so a
        // scalar-tuned cache can never answer a vec-policy tune.
        let sig = "conv[b]@8x8x8";
        let fp = mcu_fingerprint(&McuConfig::default());
        let k_scalar = cache_key(sig, &fp, "latency");
        assert_eq!(
            k_scalar,
            cache_key_backend(sig, &fp, "latency", BackendSel::Scalar),
            "legacy keys are the scalar-policy keys"
        );
        let k_vec = cache_key_backend(sig, &fp, "latency", BackendSel::Vec);
        let k_auto = cache_key_backend(sig, &fp, "latency", BackendSel::Auto);
        assert_ne!(k_scalar, k_vec);
        assert_ne!(k_scalar, k_auto);
        assert_ne!(k_vec, k_auto);
        let mut c = TuningCache::in_memory();
        c.put(k_scalar.clone(), entry(0.01));
        assert!(c.get(&k_scalar).is_some());
        assert!(c.get(&k_vec).is_none(), "vec policy must miss a scalar-tuned entry");
        assert!(c.get(&k_auto).is_none(), "auto policy must miss a scalar-tuned entry");

        // Value axis: the winning candidate's backend is part of the
        // entry and survives a JSON roundtrip — a replayed vec decision
        // deploys the vec kernel, not a silently-scalar one.
        c.put(
            k_vec.clone(),
            CacheEntry {
                candidate: Candidate {
                    kernel: KernelImpl::AsIs,
                    lowering: Lowering::Im2col { patches: 2, filters: 2 },
                    backend: Backend::VecLanes,
                },
                ..entry(0.008)
            },
        );
        let parsed = parse_entries(&Json::parse(&c.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(parsed[&k_vec].candidate.backend, Backend::VecLanes);
        assert_eq!(parsed[&k_scalar].candidate.backend, Backend::ScalarRef);

        // Schema axis: pre-backend (v2) cache files are discarded
        // wholesale by the version bump instead of being misread.
        let v2 = r#"{"version":2,"entries":{"conv[b]@8x8x8|84.000MHz-Os|latency":{"kernel":"as-is","lowering":"direct","patches":0,"filters":0,"cycles":1.0,"latency_s":0.1,"energy_mj":0.2,"mem_accesses":3,"effective_macs":4,"ram_bytes":5}}}"#;
        assert!(parse_entries(&Json::parse(v2).unwrap()).is_none());
    }

    #[test]
    fn pre_flash_v4_files_are_discarded_wholesale() {
        // v4 entries carry no flash_bytes column: both the version gate
        // and the required-field parse reject them, so a stale cache can
        // never replay into the flash-aware objective
        let v4 = r#"{"version":4,"entries":{"conv[b]@8x8x8|84.000MHz-Os|latency|scalar":{"kernel":"as-is","lowering":"direct","backend":"scalar","patches":0,"filters":0,"cycles":1.0,"latency_s":0.1,"energy_mj":0.2,"mem_accesses":3,"effective_macs":4,"ram_bytes":5}}}"#;
        assert!(parse_entries(&Json::parse(v4).unwrap()).is_none());
        // and even a doctored version number cannot smuggle a
        // flash-less entry past the parser
        let doctored = v4.replace("\"version\":4", "\"version\":5");
        assert!(parse_entries(&Json::parse(&doctored).unwrap()).is_none());
    }

    #[test]
    fn frontiers_roundtrip_and_version_gate_discards_old_files() {
        use crate::tuner::pareto::{Frontier, FrontierPoint};
        let dir = std::env::temp_dir().join("convbench-cache-test");
        let path = dir.join("frontier.json");
        let path = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);

        let frontier = Frontier::new(
            "mcunet-res".into(),
            mcu_fingerprint(&McuConfig::default()),
            "latency".into(),
            "auto".into(),
            vec![FrontierPoint {
                peak_ram_bytes: 4096,
                latency_s: 0.01,
                energy_mj: 0.3,
                flash_bytes: 9216,
                candidates: vec![Candidate {
                    kernel: KernelImpl::AsIs,
                    lowering: Lowering::Im2col { patches: 2, filters: 2 },
                    backend: Backend::VecLanes,
                }],
            }],
        );
        let key = frontier_key("g0123456789abcdefx1", "84.000MHz-Os", "latency", BackendSel::Auto);
        assert!(key.starts_with("frontier|"), "frontier keys are namespaced");

        let mut c = TuningCache::load(&path);
        c.put(cache_key("conv[x]@8x8x4", "84.000MHz-Os", "latency"), entry(0.011));
        c.put_frontier(key.clone(), frontier.clone());
        assert!(c.is_dirty());
        assert_eq!(c.frontier_len(), 1);
        // re-putting the identical frontier does not re-dirty
        c.save().expect("save cache");
        c.put_frontier(key.clone(), frontier.clone());
        assert!(!c.is_dirty());

        let warm = TuningCache::load(&path);
        assert_eq!(warm.len(), 1, "per-node entries survive alongside frontiers");
        assert_eq!(warm.get_frontier(&key), Some(&frontier));

        // pre-frontier (v3) files are discarded wholesale by the bump
        let v3 = r#"{"version":3,"entries":{}}"#;
        assert!(parse_file(&Json::parse(v3).unwrap()).is_none());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn graph_topology_change_invalidates_by_key() {
        // Same ops, same shapes — but a skip edge rewires one node's
        // input. The per-node signature folds the producer distance, so
        // the rewired node composes a different cache key instead of
        // silently replaying the linear schedule; the untouched prefix
        // keeps sharing its entries.
        use crate::models::{experiment_layer, LayerParams};
        use crate::nn::{Graph, Layer};
        use crate::quant::QParam;
        use crate::tuner::space::node_signature;

        let p = LayerParams::new(1, 3, 6, 4, 4);
        let model = experiment_layer(&p, crate::analytic::Primitive::Standard, 9);
        let conv = model.layers[0].clone();
        let build = |skip: bool| {
            let mut g = Graph::new("topo", crate::nn::Shape::new(6, 6, 4), QParam::new(7));
            let v0 = g.input();
            let v1 = g.layer(v0, conv.clone());
            let v2 = g.layer(v1, Layer::Relu);
            // linear: consume the relu output; skip: consume the conv
            // output from two steps back (same 6×6×4 shape either way)
            g.layer(if skip { v1 } else { v2 }, Layer::Relu);
            g
        };
        let chain = build(false);
        let skip = build(true);
        let (cs, ss) = (chain.value_shapes(), skip.value_shapes());
        assert_eq!(cs, ss, "the rewiring must not change any shape");
        let mcu = mcu_fingerprint(&McuConfig::default());
        // untouched nodes share keys across the two graphs
        for i in 0..2 {
            assert_eq!(
                cache_key(&node_signature(&chain.nodes[i], i, &cs), &mcu, "latency"),
                cache_key(&node_signature(&skip.nodes[i], i, &ss), &mcu, "latency"),
                "node {i}"
            );
        }
        // the rewired consumer re-keys
        let k_chain = cache_key(&node_signature(&chain.nodes[2], 2, &cs), &mcu, "latency");
        let k_skip = cache_key(&node_signature(&skip.nodes[2], 2, &ss), &mcu, "latency");
        assert_ne!(k_chain, k_skip);
        // a cache warmed on the chain answers the chain key but misses
        // the skip key — no silent linear-schedule replay
        let mut c = TuningCache::in_memory();
        c.put(k_chain.clone(), entry(0.004));
        assert!(c.get(&k_chain).is_some());
        assert!(c.get(&k_skip).is_none());
    }
}
