//! Cost-model-driven per-layer schedule auto-tuner.
//!
//! The paper's central result is that the *choice* of primitive, code
//! path (scalar vs `__SMLAD` SIMD) and register blocking dominates
//! latency and energy on Cortex-M — yet a fixed deployment hard-codes one
//! schedule for the whole model. This subsystem makes the selection
//! automatic, per layer:
//!
//! * [`space`] enumerates the legal schedule space of each layer —
//!   admissible primitive substitutions (depthwise ↔ grouped conv,
//!   pointwise ↔ zero-shift shift-conv), direct vs im2col lowering, and
//!   every (P, F) register blocking that fits the M4 register file
//!   ([`crate::nn::blocking::fits_register_file`]) — can *execute* any
//!   candidate bit-exactly (the generalized blocked matmul runs through
//!   [`crate::nn::blocking::mat_mult_block`]), and can *price* any
//!   candidate in closed form ([`space::analytic_counts`], backed by
//!   [`crate::nn::counts`]);
//! * [`search`] scores every candidate **analytically** — shape-derived
//!   op counts through the MCU cost model ([`crate::mcu::measure`]) —
//!   under a configurable [`Objective`] and emits a [`TunedSchedule`].
//!   The analytic counts are property-tested equal to the instrumented
//!   ones, so decisions are byte-identical to a simulator-scored search,
//!   but a **cold tune executes zero forwards** (shapes propagate via
//!   `Layer::output_shape`; `TuneStats::evaluations` pins 0 on cold and
//!   warm runs alike, with effort reported in `TuneStats::analytic`);
//! * [`cache`] persists decisions as JSON keyed by per-node signature
//!   (op + input shape + producer-distance topology, so residual
//!   rewirings re-key) + [`crate::mcu::McuConfig`] + objective, so a
//!   warm re-deployment does not even re-run the shape arithmetic.
//!
//! Tuning operates on the DAG graph IR ([`tune_graph_shape`]); linear
//! models are the chain-graph special case ([`tune_model_shape`]).
//! Residual joins ([`crate::nn::ResidualAdd`]) have a single scalar
//! implementation, priced by the same analytic engine.
//!
//! Beyond per-node greedy selection, [`search::tune_graph_joint`] runs
//! the same analytic search *jointly* over the whole graph under a peak-
//! SRAM budget: node working RAM is priced as the liveness-planned
//! activation peak at that step ([`crate::nn::arena::IncrementalPeak`])
//! plus candidate scratch, so the reported `peak_ram_bytes` matches what
//! [`crate::nn::plan::plan_arena`] actually packs — including residual
//! graphs, where the old in+out+scratch accounting over-priced the join.
//! [`search::tune_graph_frontier`] sweeps every distinct budget
//! threshold and emits the full latency↔RAM [`pareto::Frontier`];
//! deployment picks the cheapest point that fits `--ram-budget` at
//! serve time ([`pareto::Frontier::cheapest_within`]). Frontiers are
//! cached whole, keyed by [`space::graph_signature`] × MCU fingerprint
//! × objective × backend policy ([`cache::frontier_key`]).
//!
//! Wiring: `coordinator::pipeline::FloatModel::deploy_tuned` tunes at
//! deployment, `coordinator::server::InferenceServer::start_tuned`
//! serves tuned variants, `convbench tune` drives the Table 2 workloads
//! from the CLI, and `harness::tuned` compares tuned schedules against
//! the fixed (primitive, path) configurations — both sides priced by the
//! same analytic engine.

pub mod cache;
pub mod pareto;
pub mod search;
pub mod space;

pub use cache::{
    cache_key, cache_key_backend, frontier_key, mcu_fingerprint, CacheEntry, TuningCache,
};
pub use pareto::{Frontier, FrontierPoint};
pub use search::{
    schedule_from_candidates, simd_flags, tune_graph_budgeted, tune_graph_frontier,
    tune_graph_joint, tune_graph_shape, tune_graph_shape_backend, tune_model, tune_model_shape,
    tune_model_shape_backend, LayerDecision, TuneStats, TunedSchedule,
};
pub use space::{analytic_counts, candidates, graph_signature, Candidate, KernelImpl, Lowering};

pub use crate::nn::Backend;

/// Which host execution backends the search may choose from — the
/// CLI-facing policy axis (`--backend scalar|vec|auto`). Orthogonal to
/// [`Objective`]: the objective prices the modeled MCU event stream,
/// which is backend-invariant; the policy only restricts which
/// [`Backend`] the deployed kernels execute with on the host.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BackendSel {
    /// Scalar reference kernels only — the historical behaviour, and
    /// the default for every legacy entry point.
    #[default]
    Scalar,
    /// Host-vectorized kernels wherever the lowering admits them
    /// (im2col points); scalar elsewhere.
    Vec,
    /// Both backends enumerated; ties broken toward [`Backend::VecLanes`].
    Auto,
}

impl BackendSel {
    /// Parse a CLI spelling: `scalar`, `vec`, or `auto`.
    pub fn parse(s: &str) -> Result<BackendSel, String> {
        match s {
            "scalar" => Ok(BackendSel::Scalar),
            "vec" => Ok(BackendSel::Vec),
            "auto" => Ok(BackendSel::Auto),
            other => Err(format!("unknown backend {other:?} (scalar|vec|auto)")),
        }
    }

    /// Stable name — part of every backend-aware cache key.
    pub fn as_str(&self) -> &'static str {
        match self {
            BackendSel::Scalar => "scalar",
            BackendSel::Vec => "vec",
            BackendSel::Auto => "auto",
        }
    }
}

/// What the tuner minimizes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Objective {
    /// Simulated end-to-end latency (seconds).
    Latency,
    /// Simulated energy per inference (mJ).
    Energy,
    /// Peak working SRAM (activations + schedule scratch).
    PeakRam,
    /// Flash footprint: deployed weight bytes of the chosen kernels
    /// (post-compaction for pruned graphs; kernel substitutions that
    /// materialize extra tables — e.g. pointwise-as-shift — pay for
    /// them here).
    Flash,
    /// Weighted sum of the four (latency in ms, energy in mJ, RAM in
    /// KiB, flash in KiB, so the default weights are comparable in
    /// magnitude).
    Weighted { latency: f64, energy: f64, ram: f64, flash: f64 },
}

impl Objective {
    /// Parse a CLI spelling: `latency`, `energy`, `ram`, `flash`, or
    /// `weighted[:L,E,R[,F]]` (e.g. `weighted:1,0.5,0.1,0.05`; the
    /// three-weight spelling keeps its pre-flash meaning, F = 0).
    pub fn parse(s: &str) -> Result<Objective, String> {
        match s {
            "latency" => Ok(Objective::Latency),
            "energy" => Ok(Objective::Energy),
            "ram" => Ok(Objective::PeakRam),
            "flash" => Ok(Objective::Flash),
            "weighted" => {
                Ok(Objective::Weighted { latency: 1.0, energy: 1.0, ram: 0.1, flash: 0.0 })
            }
            other => {
                if let Some(spec) = other.strip_prefix("weighted:") {
                    let parts: Vec<&str> = spec.split(',').collect();
                    if parts.len() != 3 && parts.len() != 4 {
                        return Err(format!(
                            "weighted objective needs 3 or 4 comma-separated weights, got {other:?}"
                        ));
                    }
                    let w: Result<Vec<f64>, _> =
                        parts.iter().map(|p| p.trim().parse::<f64>()).collect();
                    match w {
                        Ok(w) => Ok(Objective::Weighted {
                            latency: w[0],
                            energy: w[1],
                            ram: w[2],
                            flash: w.get(3).copied().unwrap_or(0.0),
                        }),
                        Err(e) => Err(format!("bad weight in {other:?}: {e}")),
                    }
                } else {
                    Err(format!(
                        "unknown objective {other:?} (latency|energy|ram|flash|weighted[:L,E,R[,F]])"
                    ))
                }
            }
        }
    }

    /// Stable name — part of every cache key.
    pub fn name(&self) -> String {
        match self {
            Objective::Latency => "latency".to_string(),
            Objective::Energy => "energy".to_string(),
            Objective::PeakRam => "ram".to_string(),
            Objective::Flash => "flash".to_string(),
            Objective::Weighted { latency, energy, ram, flash } => {
                format!("weighted:{latency},{energy},{ram},{flash}")
            }
        }
    }

    /// The scalar the search minimizes.
    pub fn score(&self, latency_s: f64, energy_mj: f64, ram_bytes: usize, flash_bytes: usize) -> f64 {
        match self {
            Objective::Latency => latency_s,
            Objective::Energy => energy_mj,
            Objective::PeakRam => ram_bytes as f64,
            Objective::Flash => flash_bytes as f64,
            Objective::Weighted { latency, energy, ram, flash } => {
                latency * latency_s * 1e3
                    + energy * energy_mj
                    + ram * ram_bytes as f64 / 1024.0
                    + flash * flash_bytes as f64 / 1024.0
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_sel_spellings_roundtrip() {
        for sel in [BackendSel::Scalar, BackendSel::Vec, BackendSel::Auto] {
            assert_eq!(BackendSel::parse(sel.as_str()), Ok(sel));
        }
        assert!(BackendSel::parse("simd").is_err());
        assert_eq!(BackendSel::default(), BackendSel::Scalar);
    }

    #[test]
    fn objective_parse_spellings() {
        assert_eq!(Objective::parse("latency"), Ok(Objective::Latency));
        assert_eq!(Objective::parse("energy"), Ok(Objective::Energy));
        assert_eq!(Objective::parse("ram"), Ok(Objective::PeakRam));
        assert_eq!(Objective::parse("flash"), Ok(Objective::Flash));
        assert_eq!(
            Objective::parse("weighted"),
            Ok(Objective::Weighted { latency: 1.0, energy: 1.0, ram: 0.1, flash: 0.0 })
        );
        // the pre-flash three-weight spelling keeps its meaning (F = 0)
        assert_eq!(
            Objective::parse("weighted:2,0.5,0"),
            Ok(Objective::Weighted { latency: 2.0, energy: 0.5, ram: 0.0, flash: 0.0 })
        );
        assert_eq!(
            Objective::parse("weighted:1,0,0,0.25"),
            Ok(Objective::Weighted { latency: 1.0, energy: 0.0, ram: 0.0, flash: 0.25 })
        );
        assert!(Objective::parse("speed").is_err());
        assert!(Objective::parse("weighted:1,2").is_err());
        assert!(Objective::parse("weighted:1,2,3,4,5").is_err());
        assert!(Objective::parse("weighted:a,b,c").is_err());
    }

    #[test]
    fn objective_names_are_distinct_cache_key_parts() {
        let names: Vec<String> = [
            Objective::Latency,
            Objective::Energy,
            Objective::PeakRam,
            Objective::Flash,
            Objective::Weighted { latency: 1.0, energy: 1.0, ram: 0.1, flash: 0.0 },
            Objective::Weighted { latency: 2.0, energy: 1.0, ram: 0.1, flash: 0.0 },
            Objective::Weighted { latency: 1.0, energy: 1.0, ram: 0.1, flash: 0.05 },
        ]
        .iter()
        .map(|o| o.name())
        .collect();
        for i in 0..names.len() {
            for j in i + 1..names.len() {
                assert_ne!(names[i], names[j]);
            }
        }
    }

    #[test]
    fn scores_select_the_right_metric() {
        // candidate A: fast but RAM- and flash-hungry; B: slow but small
        let a = (0.001f64, 0.05f64, 64 * 1024usize, 48 * 1024usize);
        let b = (0.010f64, 0.40f64, 4 * 1024usize, 6 * 1024usize);
        let lat = Objective::Latency;
        let en = Objective::Energy;
        let ram = Objective::PeakRam;
        let fl = Objective::Flash;
        assert!(lat.score(a.0, a.1, a.2, a.3) < lat.score(b.0, b.1, b.2, b.3));
        assert!(en.score(a.0, a.1, a.2, a.3) < en.score(b.0, b.1, b.2, b.3));
        assert!(ram.score(a.0, a.1, a.2, a.3) > ram.score(b.0, b.1, b.2, b.3));
        assert!(fl.score(a.0, a.1, a.2, a.3) > fl.score(b.0, b.1, b.2, b.3));
        // a RAM-dominated weighting flips the preference
        let w = Objective::Weighted { latency: 0.0, energy: 0.0, ram: 1.0, flash: 0.0 };
        assert!(w.score(a.0, a.1, a.2, a.3) > w.score(b.0, b.1, b.2, b.3));
        // and so does a flash-dominated one
        let f = Objective::Weighted { latency: 1.0, energy: 0.0, ram: 0.0, flash: 1e6 };
        assert!(f.score(a.0, a.1, a.2, a.3) > f.score(b.0, b.1, b.2, b.3));
    }
}
