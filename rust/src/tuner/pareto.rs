//! The latency↔RAM Pareto frontier a joint graph tune emits: every
//! non-dominated trade between peak working SRAM and the tuned
//! objective, each point carrying the full per-node candidate schedule
//! that realizes it. Deployment picks a point *at serve time* — the
//! cheapest one that fits the target's `--ram-budget` — instead of
//! re-searching, and frontiers round-trip through JSON
//! ([`crate::util::json`]) so the tuning cache can replay them wholesale
//! ([`crate::tuner::cache`]).

use crate::nn::Backend;
use crate::util::json::Json;

use super::space::{Candidate, KernelImpl, Lowering};

/// One point on the frontier: a complete per-node schedule, its peak
/// working RAM (liveness-planned activations + scratch, maximized over
/// steps) and its analytic totals.
#[derive(Clone, Debug, PartialEq)]
pub struct FrontierPoint {
    /// Peak working SRAM of this schedule — what the claim
    /// `workspace ≥ peak` is tested against after compilation.
    pub peak_ram_bytes: usize,
    /// Analytic end-to-end latency (seconds).
    pub latency_s: f64,
    /// Analytic energy per inference (mJ).
    pub energy_mj: f64,
    /// Flash footprint of this schedule: weight/bias/table bytes summed
    /// over the chosen candidates (lowering re-layouts are free; only
    /// materialized tables — e.g. the pointwise-as-shift table — and
    /// channel compaction move this number).
    pub flash_bytes: usize,
    /// The per-node candidate assignment realizing this point (one per
    /// graph node, in topo order) — the input to
    /// [`crate::tuner::search::schedule_from_candidates`].
    pub candidates: Vec<Candidate>,
}

/// A model's full latency↔RAM frontier on one MCU configuration under
/// one objective and backend policy. Canonical ordering: peak ascending,
/// latency strictly descending (dominated and duplicate points are
/// eliminated on construction), so the first point is the smallest
/// feasible deployment and the last is the unconstrained optimum.
#[derive(Clone, Debug, PartialEq)]
pub struct Frontier {
    pub model: String,
    /// MCU fingerprint the measurements are valid for.
    pub mcu: String,
    pub objective: String,
    /// Backend policy the schedules were searched under.
    pub backend: String,
    pub points: Vec<FrontierPoint>,
}

impl Frontier {
    /// Build a frontier from raw candidate points: sort (peak asc,
    /// latency asc), then keep a point only when it strictly improves
    /// latency over everything kept so far. A point survives iff no
    /// other point is ≤ in both coordinates and < in one — the standard
    /// dominated-point elimination — and the survivors come out in the
    /// canonical stable order.
    pub fn new(
        model: String,
        mcu: String,
        objective: String,
        backend: String,
        mut points: Vec<FrontierPoint>,
    ) -> Frontier {
        points.sort_by(|a, b| {
            a.peak_ram_bytes.cmp(&b.peak_ram_bytes).then(
                a.latency_s
                    .partial_cmp(&b.latency_s)
                    .unwrap_or(std::cmp::Ordering::Equal),
            )
        });
        let mut kept: Vec<FrontierPoint> = Vec::new();
        for p in points {
            if kept
                .last()
                .map(|k| p.latency_s < k.latency_s)
                .unwrap_or(true)
            {
                kept.push(p);
            }
        }
        Frontier { model, mcu, objective, backend, points: kept }
    }

    /// The lowest-latency point whose peak fits `budget` — the point a
    /// deployment with `--ram-budget` compiles. With the canonical order
    /// that is simply the last fitting point. `None` when even the
    /// smallest point exceeds the budget.
    pub fn cheapest_within(&self, budget: usize) -> Option<&FrontierPoint> {
        self.points
            .iter()
            .filter(|p| p.peak_ram_bytes <= budget)
            .last()
    }

    /// The unconstrained optimum (last point in canonical order).
    pub fn best(&self) -> Option<&FrontierPoint> {
        self.points.last()
    }

    /// The smallest-RAM feasible deployment (first point).
    pub fn min_peak(&self) -> Option<&FrontierPoint> {
        self.points.first()
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Serialize (the cache embeds this under its `frontiers` map; the
    /// CLI writes it standalone via `--pareto-out`).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .field("model", self.model.as_str())
            .field("mcu", self.mcu.as_str())
            .field("objective", self.objective.as_str())
            .field("backend", self.backend.as_str())
            .field(
                "points",
                Json::Arr(self.points.iter().map(point_to_json).collect()),
            )
    }

    /// Parse what [`Frontier::to_json`] emits. `None` on any structural
    /// mismatch (the caller treats that as a cache miss).
    pub fn from_json(json: &Json) -> Option<Frontier> {
        let mut points = Vec::new();
        for p in json.get("points")?.as_arr()? {
            points.push(point_from_json(p)?);
        }
        Some(Frontier {
            model: json.get("model")?.as_str()?.to_string(),
            mcu: json.get("mcu")?.as_str()?.to_string(),
            objective: json.get("objective")?.as_str()?.to_string(),
            backend: json.get("backend")?.as_str()?.to_string(),
            points,
        })
    }
}

fn candidate_to_json(c: &Candidate) -> Json {
    let (lowering, patches, filters) = match c.lowering {
        Lowering::Direct => ("direct", 0usize, 0usize),
        Lowering::Im2col { patches, filters } => ("im2col", patches, filters),
    };
    Json::obj()
        .field("kernel", c.kernel.as_str())
        .field("lowering", lowering)
        .field("patches", patches)
        .field("filters", filters)
        .field("backend", c.backend.as_str())
}

fn candidate_from_json(json: &Json) -> Option<Candidate> {
    let kernel = KernelImpl::parse(json.get("kernel")?.as_str()?).ok()?;
    let lowering = match json.get("lowering")?.as_str()? {
        "direct" => Lowering::Direct,
        "im2col" => Lowering::Im2col {
            patches: json.get("patches")?.as_i64()? as usize,
            filters: json.get("filters")?.as_i64()? as usize,
        },
        _ => return None,
    };
    let backend = Backend::parse(json.get("backend")?.as_str()?).ok()?;
    Some(Candidate { kernel, lowering, backend })
}

fn point_to_json(p: &FrontierPoint) -> Json {
    Json::obj()
        .field("peak_ram_bytes", p.peak_ram_bytes)
        .field("latency_s", p.latency_s)
        .field("energy_mj", p.energy_mj)
        .field("flash_bytes", p.flash_bytes)
        .field(
            "candidates",
            Json::Arr(p.candidates.iter().map(candidate_to_json).collect()),
        )
}

fn point_from_json(json: &Json) -> Option<FrontierPoint> {
    let mut candidates = Vec::new();
    for c in json.get("candidates")?.as_arr()? {
        candidates.push(candidate_from_json(c)?);
    }
    Some(FrontierPoint {
        peak_ram_bytes: json.get("peak_ram_bytes")?.as_i64()? as usize,
        latency_s: json.get("latency_s")?.as_f64()?,
        energy_mj: json.get("energy_mj")?.as_f64()?,
        flash_bytes: json.get("flash_bytes")?.as_i64()? as usize,
        candidates,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(peak: usize, lat: f64) -> FrontierPoint {
        FrontierPoint {
            peak_ram_bytes: peak,
            latency_s: lat,
            energy_mj: lat * 30.0,
            flash_bytes: peak * 3,
            candidates: vec![
                Candidate {
                    kernel: KernelImpl::AsIs,
                    lowering: Lowering::Im2col { patches: 2, filters: 2 },
                    backend: Backend::VecLanes,
                },
                Candidate {
                    kernel: KernelImpl::AsIs,
                    lowering: Lowering::Direct,
                    backend: Backend::ScalarRef,
                },
            ],
        }
    }

    fn frontier(points: Vec<FrontierPoint>) -> Frontier {
        Frontier::new(
            "m".into(),
            "84.000MHz-Os".into(),
            "latency".into(),
            "auto".into(),
            points,
        )
    }

    #[test]
    fn dominated_points_are_eliminated_and_order_is_canonical() {
        let f = frontier(vec![
            pt(300, 0.5),  // dominated by (200, 0.5): same latency, more RAM
            pt(100, 1.0),
            pt(200, 0.5),
            pt(150, 1.2),  // dominated by (100, 1.0) in both coordinates
            pt(100, 1.1),  // duplicate peak, worse latency
        ]);
        let got: Vec<(usize, f64)> =
            f.points.iter().map(|p| (p.peak_ram_bytes, p.latency_s)).collect();
        assert_eq!(got, vec![(100, 1.0), (200, 0.5)]);
        // peak strictly ascending, latency strictly descending
        for w in f.points.windows(2) {
            assert!(w[0].peak_ram_bytes < w[1].peak_ram_bytes);
            assert!(w[0].latency_s > w[1].latency_s);
        }
    }

    #[test]
    fn cheapest_within_picks_the_fastest_fitting_point() {
        let f = frontier(vec![pt(100, 1.0), pt(200, 0.5), pt(400, 0.25)]);
        assert!(f.cheapest_within(50).is_none(), "below the smallest point");
        assert_eq!(f.cheapest_within(100).unwrap().peak_ram_bytes, 100);
        assert_eq!(f.cheapest_within(399).unwrap().peak_ram_bytes, 200);
        assert_eq!(f.cheapest_within(usize::MAX).unwrap().peak_ram_bytes, 400);
        assert_eq!(f.best().unwrap().latency_s, 0.25);
        assert_eq!(f.min_peak().unwrap().peak_ram_bytes, 100);
        assert_eq!(f.len(), 3);
        assert!(!f.is_empty());
    }

    #[test]
    fn json_roundtrip_is_identical() {
        let f = frontier(vec![pt(100, 1.0), pt(200, 0.5)]);
        let text = f.to_json().to_string();
        let back = Frontier::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(f, back);
    }

    #[test]
    fn malformed_json_is_rejected_not_misread() {
        assert!(Frontier::from_json(&Json::parse(r#"{"model":"m"}"#).unwrap()).is_none());
        let bad_kernel = r#"{"model":"m","mcu":"f","objective":"latency","backend":"auto",
            "points":[{"peak_ram_bytes":1,"latency_s":0.1,"energy_mj":0.2,
                       "candidates":[{"kernel":"warp","lowering":"direct",
                                      "patches":0,"filters":0,"backend":"scalar"}]}]}"#;
        assert!(Frontier::from_json(&Json::parse(bad_kernel).unwrap()).is_none());
    }
}
