//! The schedule search: for every node of a graph (linear models lower
//! to chain graphs), score every schedule-space candidate
//! **analytically** — closed-form op counts
//! ([`crate::tuner::space::analytic_counts`], plus
//! [`crate::nn::counts::residual_add_counts`] for residual joins) mapped
//! through the MCU cycle/energy model ([`crate::mcu::measure`]) — under
//! the configured objective, keep the winner, and assemble a
//! [`TunedSchedule`]. The analytic counts equal the instrumented ones
//! exactly (property-tested), so the decisions are byte-identical to the
//! original simulator-scored search while a cold tune costs shape
//! arithmetic instead of thousands of instrumented forwards; activation
//! shapes propagate through [`crate::nn::Graph::value_shapes`], so
//! tuning executes **zero** forwards. Node decisions are independent
//! because the engine fixes activation formats at deployment time, so
//! per-node minimization is globally optimal for additive objectives —
//! and therefore never worse than any fixed (primitive, path)
//! configuration the sweep harness measures. Cache keys are per-node
//! signatures ([`space::node_signature`]), which fold the node's input
//! topology: adding a skip edge re-keys, so a linear schedule is never
//! silently replayed onto a rewired graph.

use crate::mcu::{measure, McuConfig, Measurement};
use crate::nn::arena::{slot_layout, IncrementalPeak, ValueInterval};
use crate::nn::{counts, ExecPlan, Graph, Model, Monitor, Node, NodeOp, Shape, Tensor, Workspace};

use super::cache::{cache_key_backend, frontier_key, mcu_fingerprint, CacheEntry, TuningCache};
use super::pareto::{Frontier, FrontierPoint};
use super::space::{self, Candidate, KernelImpl, Lowering};
use super::{BackendSel, Objective};
use crate::nn::Backend;

/// The tuned decision for one layer.
#[derive(Clone, Debug)]
pub struct LayerDecision {
    pub index: usize,
    pub layer: &'static str,
    pub candidate: Candidate,
    pub cycles: f64,
    pub latency_s: f64,
    pub energy_mj: f64,
    pub mem_accesses: u64,
    pub effective_macs: u64,
    /// Working SRAM while this node runs: the live activation bytes at
    /// this step under the deployment arena layout (the same
    /// [`crate::nn::arena::plan_arena`] packing the compiled plan binds)
    /// plus the candidate's scratch. Equals
    /// `ExecPlan::step_live_bytes(i) + ExecPlan::layer_scratch_bytes(i)`
    /// of the compiled plan, so the schedule's claimed peak matches what
    /// the arena actually provisions — including on residual graphs,
    /// where the old input+output pricing double-counted join operands
    /// that the liveness planner overlaps with dead bodies.
    pub ram_bytes: usize,
    /// Deployed weight bytes of this node under the chosen candidate
    /// ([`space::flash_bytes`]): the layer's weight/bias payload plus any
    /// materialized tables (pointwise-as-shift pays its shift table).
    /// Post-compaction for pruned graphs — masked channels cost nothing.
    pub flash_bytes: usize,
    /// Whether the decision was replayed from the tuning cache.
    pub from_cache: bool,
}

/// A tuned per-layer schedule for one model on one MCU configuration.
#[derive(Clone, Debug)]
pub struct TunedSchedule {
    pub model: String,
    /// MCU fingerprint the measurements are valid for.
    pub mcu: String,
    pub objective: String,
    pub layers: Vec<LayerDecision>,
    /// Sum of per-layer simulated latencies.
    pub latency_s: f64,
    /// Sum of per-layer simulated energies.
    pub energy_mj: f64,
    /// Max of per-layer working RAM ([`LayerDecision::ram_bytes`]):
    /// liveness-planned live activation bytes + scratch, maximized over
    /// steps — byte-equal to the compiled plan's arena peak plus the
    /// peak step's scratch.
    pub peak_ram_bytes: usize,
    /// Sum of per-layer deployed weight bytes
    /// ([`LayerDecision::flash_bytes`]) — the model's flash footprint
    /// under this schedule.
    pub flash_bytes: usize,
}

/// Search-effort accounting. Since the analytic cost engine landed,
/// `evaluations` (instrumented simulator runs) is **zero on cold and
/// warm tunes alike** — the field remains so the CI gates and dashboards
/// can pin that invariant; search effort shows up in `analytic` instead.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TuneStats {
    /// Instrumented simulator evaluations (kernel executions under a
    /// counting monitor). Always 0: scoring is analytic.
    pub evaluations: usize,
    /// Candidates scored analytically (closed-form counts → cost model).
    pub analytic: usize,
    /// Layers answered from the cache without any scoring at all.
    pub cache_hits: usize,
    /// Candidates considered (scored + replayed).
    pub candidates: usize,
}

impl TunedSchedule {
    /// Execute the model under this schedule through the allocating
    /// *reference* executor ([`space::execute`] per layer) — same
    /// bit-exact outputs as `Model::forward`; only the event stream
    /// differs. Deployed paths compile once and run allocation-free via
    /// [`TunedSchedule::run_in`] / [`ExecPlan::run_in`]; this path stays
    /// as the oracle those are property-tested against.
    pub fn run<M: Monitor>(&self, model: &Model, x: &Tensor, mon: &mut M) -> Tensor {
        assert_eq!(x.shape, model.input_shape, "model input shape mismatch");
        assert_eq!(self.layers.len(), model.layers.len(), "schedule/model mismatch");
        let mut t = x.clone();
        for (layer, d) in model.layers.iter().zip(&self.layers) {
            t = space::execute(layer, &d.candidate, &t, mon);
        }
        t
    }

    /// Execute a *graph* under this schedule through the allocating
    /// reference executor ([`Graph::execute_reference`]) — the DAG
    /// analog of [`TunedSchedule::run`], and the oracle the compiled
    /// engine is property-tested against on residual topologies.
    pub fn run_graph<M: Monitor>(&self, graph: &Graph, x: &Tensor, mon: &mut M) -> Tensor {
        assert_eq!(self.layers.len(), graph.nodes.len(), "schedule/graph mismatch");
        graph.execute_reference(&self.candidates(), x, mon)
    }

    /// The per-node candidate schedule as a plain list (the input to
    /// [`ExecPlan::compile`] / [`ExecPlan::compile_graph`]).
    pub fn candidates(&self) -> Vec<Candidate> {
        self.layers.iter().map(|d| d.candidate).collect()
    }

    /// Compile this schedule against its model into the zero-allocation
    /// engine executor.
    pub fn compile(&self, model: &Model) -> ExecPlan {
        assert_eq!(self.layers.len(), model.layers.len(), "schedule/model mismatch");
        ExecPlan::compile(model, &self.candidates())
    }

    /// [`TunedSchedule::compile`] for graph deployments.
    pub fn compile_graph(&self, graph: &Graph) -> ExecPlan {
        assert_eq!(self.layers.len(), graph.nodes.len(), "schedule/graph mismatch");
        ExecPlan::compile_graph(graph, &self.candidates())
    }

    /// Plan (and bind) the inference arena for this schedule: the
    /// workspace [`TunedSchedule::run_in`] needs, holding the compiled
    /// plan so the steady-state path never recompiles or allocates.
    pub fn workspace(&self, model: &Model) -> Workspace {
        Workspace::bind(self.compile(model))
    }

    /// [`TunedSchedule::workspace`] for graph deployments.
    pub fn workspace_graph(&self, graph: &Graph) -> Workspace {
        Workspace::bind(self.compile_graph(graph))
    }

    /// [`TunedSchedule::workspace`] with batched-I/O staging for up to
    /// `max_batch` samples — the arena
    /// [`TunedSchedule::run_batch_in`] drives. Compute capacity is
    /// per-sample (batching never widens the arena); only the
    /// input/output staging lanes scale with `max_batch`.
    pub fn workspace_batch(&self, model: &Model, max_batch: usize) -> Workspace {
        Workspace::bind_batch(self.compile(model), max_batch)
    }

    /// [`TunedSchedule::workspace_batch`] for graph deployments.
    pub fn workspace_graph_batch(&self, graph: &Graph, max_batch: usize) -> Workspace {
        Workspace::bind_batch(self.compile_graph(graph), max_batch)
    }

    /// Execute one inference through the compiled engine inside a
    /// pre-planned arena from [`TunedSchedule::workspace`]: bit-exact
    /// and `CountingMonitor`-event-identical to [`TunedSchedule::run`]
    /// (property-tested across the entire candidate space in
    /// `nn::plan`), with **zero** heap allocations in steady state
    /// (pinned by `benches/infer_hot.rs`).
    ///
    /// The executable weights live in the workspace's *bound plan*, not
    /// in the schedule (a `TunedSchedule` is pure decision data), so the
    /// workspace must be rebuilt on any redeployment: the asserts below
    /// catch a mismatched model name or candidate schedule, but a
    /// same-named, same-schedule redeploy with new weights must call
    /// [`TunedSchedule::workspace`] again — the bound plan is the
    /// deployment.
    ///
    /// ```
    /// use convbench::analytic::Primitive;
    /// use convbench::mcu::McuConfig;
    /// use convbench::models::mcunet;
    /// use convbench::nn::{NoopMonitor, Tensor};
    /// use convbench::tuner::{tune_model_shape, Objective, TuningCache};
    ///
    /// let model = mcunet(Primitive::DepthwiseSeparable, 42);
    /// let mut cache = TuningCache::in_memory();
    /// let (sched, _) =
    ///     tune_model_shape(&model, &McuConfig::default(), Objective::Latency, &mut cache);
    ///
    /// // bind the compiled plan + arena once, run forever without allocating
    /// let mut ws = sched.workspace(&model);
    /// let x = Tensor::zeros(model.input_shape, model.input_q);
    /// let tuned = sched.run_in(&x, &mut ws, &mut NoopMonitor).data.clone();
    ///
    /// // bit-exact with the allocating reference executor
    /// let reference = sched.run(&model, &x, &mut NoopMonitor);
    /// assert_eq!(tuned, reference.data);
    /// ```
    pub fn run_in<'w, M: Monitor>(
        &self,
        x: &Tensor,
        ws: &'w mut Workspace,
        mon: &mut M,
    ) -> &'w Tensor {
        let plan = ws.bound.take().expect(
            "workspace holds no bound plan — build it with TunedSchedule::workspace \
             (or drive ExecPlan::run_in directly)",
        );
        assert_eq!(
            plan.model_name(),
            self.model,
            "workspace-bound plan was compiled for a different model"
        );
        assert_eq!(
            plan.schedule_fingerprint(),
            crate::nn::plan::candidate_fingerprint(self.layers.iter().map(|d| d.candidate)),
            "workspace-bound plan was compiled for a different schedule than {:?}/{}",
            self.model,
            self.objective
        );
        let out_slot = plan.run_steps(x, ws, mon);
        ws.bound = Some(plan);
        ws.output(out_slot)
    }

    /// Execute a **micro-batch** through the bound plan
    /// ([`crate::nn::ExecPlan::run_batch_in`]): every sample runs the
    /// full compiled schedule before the next starts, reusing the
    /// arena's liveness slots, column arena and pre-widened weights
    /// across the batch. Bit-exact per lane with `batch.len()`
    /// sequential [`TunedSchedule::run_in`] calls, zero steady-state
    /// allocations. Requires an arena with staging lanes
    /// ([`TunedSchedule::workspace_batch`]); the same
    /// rebuild-on-redeploy contract as [`TunedSchedule::run_in`]
    /// applies.
    pub fn run_batch_in<'w, M: Monitor>(
        &self,
        batch: &[Tensor],
        ws: &'w mut Workspace,
        mon: &mut M,
    ) -> &'w [i8] {
        let plan = ws.bound.take().expect(
            "workspace holds no bound plan — build it with TunedSchedule::workspace_batch \
             (or drive ExecPlan::run_batch_in directly)",
        );
        assert_eq!(
            plan.model_name(),
            self.model,
            "workspace-bound plan was compiled for a different model"
        );
        assert_eq!(
            plan.schedule_fingerprint(),
            crate::nn::plan::candidate_fingerprint(self.layers.iter().map(|d| d.candidate)),
            "workspace-bound plan was compiled for a different schedule than {:?}/{}",
            self.model,
            self.objective
        );
        plan.run_batch_steps(batch, ws, mon);
        let out_len = batch.len() * plan.output_len();
        ws.bound = Some(plan);
        &ws.batch_out[..out_len]
    }

    /// Collapse the schedule totals into a [`Measurement`] (power is the
    /// latency-weighted average, as in [`crate::mcu::combine`]).
    pub fn as_measurement(&self) -> Measurement {
        let cycles: f64 = self.layers.iter().map(|d| d.cycles).sum();
        let mem_accesses: u64 = self.layers.iter().map(|d| d.mem_accesses).sum();
        let effective_macs: u64 = self.layers.iter().map(|d| d.effective_macs).sum();
        Measurement {
            cycles,
            latency_s: self.latency_s,
            power_mw: if self.latency_s > 0.0 {
                self.energy_mj / self.latency_s
            } else {
                0.0
            },
            energy_mj: self.energy_mj,
            mem_accesses,
            effective_macs,
        }
    }

    /// Markdown rendering (one row per layer plus totals).
    pub fn to_markdown(&self) -> String {
        let mut s = format!(
            "**{}** — objective {}, MCU {}\n\n\
             | # | layer | kernel | lowering | backend | latency (ms) | energy (µJ) | RAM (B) | flash (B) | cached |\n\
             |---|---|---|---|---|---|---|---|---|---|\n",
            self.model, self.objective, self.mcu
        );
        for d in &self.layers {
            s.push_str(&format!(
                "| {} | {} | {} | {} | {} | {:.4} | {:.3} | {} | {} | {} |\n",
                d.index,
                d.layer,
                d.candidate.kernel.as_str(),
                d.candidate.lowering.as_str(),
                d.candidate.backend.as_str(),
                1e3 * d.latency_s,
                1e3 * d.energy_mj,
                d.ram_bytes,
                d.flash_bytes,
                if d.from_cache { "yes" } else { "no" }
            ));
        }
        s.push_str(&format!(
            "| — | **total** | | | | {:.4} | {:.3} | {} (peak) | {} | |\n",
            1e3 * self.latency_s,
            1e3 * self.energy_mj,
            self.peak_ram_bytes,
            self.flash_bytes
        ));
        s
    }
}

fn decision_from_entry(
    index: usize,
    layer: &'static str,
    e: &CacheEntry,
    from_cache: bool,
) -> LayerDecision {
    LayerDecision {
        index,
        layer,
        candidate: e.candidate,
        cycles: e.cycles,
        latency_s: e.latency_s,
        energy_mj: e.energy_mj,
        mem_accesses: e.mem_accesses,
        effective_macs: e.effective_macs,
        ram_bytes: e.ram_bytes,
        flash_bytes: e.flash_bytes,
        from_cache,
    }
}

/// Score one candidate on one layer shape: closed-form op counts mapped
/// through the MCU cost model — O(1) shape arithmetic, no execution.
fn score_candidate(
    layer: &crate::nn::Layer,
    cand: &Candidate,
    in_shape: &Shape,
    cfg: &McuConfig,
) -> (CacheEntry, Measurement) {
    let counts = space::analytic_counts(layer, cand, in_shape);
    let m = measure(&counts, cand.lowering.path_class(), cfg);
    (
        CacheEntry {
            candidate: *cand,
            cycles: m.cycles,
            latency_s: m.latency_s,
            energy_mj: m.energy_mj,
            mem_accesses: m.mem_accesses,
            effective_macs: m.effective_macs,
            ram_bytes: space::ram_bytes(layer, cand, in_shape),
            flash_bytes: space::flash_bytes(layer, cand),
        },
        m,
    )
}

/// Tune every layer of `model` for `objective` on `cfg`, consulting (and
/// filling) `cache`. `x` is a representative input — scoring is purely
/// shape-driven (only `x.shape` is consulted; no forward is executed).
/// Prefer [`tune_model_shape`] when no input tensor is at hand.
pub fn tune_model(
    model: &Model,
    x: &Tensor,
    cfg: &McuConfig,
    objective: Objective,
    cache: &mut TuningCache,
) -> (TunedSchedule, TuneStats) {
    assert_eq!(x.shape, model.input_shape, "model input shape mismatch");
    tune_model_shape(model, cfg, objective, cache)
}

/// Tune from shapes alone: the analytic scoring needs no input data, so
/// a cold tune performs zero forwards. Linear models are the chain-graph
/// special case of [`tune_graph_shape`]; the lowering clones the layer
/// list once per call (deploy-time cost, not on any inference path).
pub fn tune_model_shape(
    model: &Model,
    cfg: &McuConfig,
    objective: Objective,
    cache: &mut TuningCache,
) -> (TunedSchedule, TuneStats) {
    tune_graph_shape(&Graph::from_model(model), cfg, objective, cache)
}

/// [`tune_model_shape`] under an explicit host-backend policy.
pub fn tune_model_shape_backend(
    model: &Model,
    cfg: &McuConfig,
    objective: Objective,
    backend: BackendSel,
    cache: &mut TuningCache,
) -> (TunedSchedule, TuneStats) {
    tune_graph_shape_backend(&Graph::from_model(model), cfg, objective, backend, cache)
}

/// Legal candidates of a graph node under a backend policy: the layer's
/// schedule space, or the single scalar implementation of the residual
/// join, filtered/ordered so the search can only deploy backends the
/// policy allows:
///
/// * `Scalar` — scalar-reference candidates only (the historical space,
///   byte-identical decisions to every pre-backend release);
/// * `Vec` — vectorized twins only wherever the node has any (im2col
///   points); nodes without vec twins (residual joins, direct-only
///   spaces) keep their scalar candidates, since *some* kernel must run;
/// * `Auto` — the full space, stably reordered vec-first: the modeled
///   MCU event stream is backend-invariant, so a vec twin always scores
///   exactly equal to its scalar sibling, and the first-strict-less
///   argmin then resolves the tie toward the faster host kernel.
fn node_candidates(node: &Node, backend: BackendSel) -> Vec<Candidate> {
    let mut cands = match &node.op {
        NodeOp::Layer(l) => space::candidates(l),
        NodeOp::Add(_) => {
            vec![Candidate {
                kernel: KernelImpl::AsIs,
                lowering: Lowering::Direct,
                backend: Backend::ScalarRef,
            }]
        }
    };
    match backend {
        BackendSel::Scalar => cands.retain(|c| c.backend == Backend::ScalarRef),
        BackendSel::Vec => {
            if cands.iter().any(|c| c.backend == Backend::VecLanes) {
                cands.retain(|c| c.backend == Backend::VecLanes);
            }
        }
        // stable partition, vec twins first (sort_by_key is stable and
        // false < true), preserving enumeration order within each block
        BackendSel::Auto => cands.sort_by_key(|c| c.backend == Backend::ScalarRef),
    }
    cands
}

/// [`space::applies`] for graph nodes (cache-replay validation).
fn node_applies(node: &Node, cand: &Candidate) -> bool {
    match &node.op {
        NodeOp::Layer(l) => space::applies(l, cand),
        NodeOp::Add(_) => cand.kernel == KernelImpl::AsIs && cand.lowering == Lowering::Direct,
    }
}

/// Score one candidate on one graph node: closed-form op counts mapped
/// through the MCU cost model — O(1) shape arithmetic, no execution.
/// The residual join's RAM charges both operands plus the output (the
/// skip operand stays resident through the join).
fn score_node_candidate(
    node: &Node,
    cand: &Candidate,
    value_shapes: &[Shape],
    cfg: &McuConfig,
) -> (CacheEntry, Measurement) {
    match &node.op {
        NodeOp::Layer(l) => score_candidate(l, cand, &value_shapes[node.inputs[0]], cfg),
        NodeOp::Add(_) => {
            let in_shape = value_shapes[node.inputs[0]];
            let c = counts::residual_add_counts(&in_shape);
            let m = measure(&c, cand.lowering.path_class(), cfg);
            let ram = node
                .inputs
                .iter()
                .map(|&v| value_shapes[v].len())
                .sum::<usize>()
                + in_shape.len();
            (
                CacheEntry {
                    candidate: *cand,
                    cycles: m.cycles,
                    latency_s: m.latency_s,
                    energy_mj: m.energy_mj,
                    mem_accesses: m.mem_accesses,
                    effective_macs: m.effective_macs,
                    ram_bytes: ram,
                    flash_bytes: 0,
                },
                m,
            )
        }
    }
}

/// Scratch a node's candidate needs beyond the activation arena. The
/// residual join works in place on arena slots — no scratch.
fn node_scratch_bytes(node: &Node, cand: &Candidate, value_shapes: &[Shape]) -> usize {
    match &node.op {
        NodeOp::Layer(l) => space::scratch_bytes(l, cand, &value_shapes[node.inputs[0]]),
        NodeOp::Add(_) => 0,
    }
}

/// Candidate-independent activation liveness of a graph: per-step live
/// byte peaks under the deployment arena layout. Built exactly as
/// [`ExecPlan::compile_graph`] builds its arena — the same value
/// intervals, the best-fit packing grown through [`IncrementalPeak`]
/// one value per topo step (byte-identical to the batch
/// [`crate::nn::arena::best_fit_layout`] after every push), and
/// [`crate::nn::arena::plan_arena`]'s reporting rule against the
/// slot-partition total — so `max(step peak)` equals the compiled plan's
/// arena peak and each entry equals `ExecPlan::step_live_bytes`.
fn act_step_peaks(graph: &Graph, shapes: &[Shape]) -> Vec<usize> {
    if graph.nodes.is_empty() {
        return Vec::new();
    }
    let last_use = graph.last_uses();
    let vals: Vec<ValueInterval> = shapes
        .iter()
        .enumerate()
        .map(|(v, s)| ValueInterval {
            size: s.len(),
            def: v.saturating_sub(1),
            last_use: last_use[v],
        })
        .collect();
    // the incremental walk the joint search prunes with: one push per
    // value in topo order, never a from-scratch replan
    let mut incr = IncrementalPeak::new();
    for &v in &vals {
        incr.push(v);
    }
    let best = incr.layout();
    // plan_arena's reporting rule: the slot partition caps the packing
    let slots = slot_layout(&vals);
    let slot_total: usize = slots.caps.iter().sum();
    let offsets: Vec<usize> = if best.peak_bytes <= slot_total {
        best.offsets
    } else {
        let mut slot_off = vec![0usize; slots.caps.len()];
        let mut acc = 0usize;
        for (off, cap) in slot_off.iter_mut().zip(&slots.caps) {
            *off = acc;
            acc += cap;
        }
        slots.slot_of.iter().map(|&s| slot_off[s]).collect()
    };
    let mut peaks = vec![0usize; graph.nodes.len()];
    for (v, val) in vals.iter().enumerate() {
        if val.size == 0 {
            continue;
        }
        for p in &mut peaks[val.def..=val.last_use] {
            *p = (*p).max(offsets[v] + val.size);
        }
    }
    peaks
}

/// Tune every node of a graph for `objective` on `cfg`, consulting (and
/// filling) `cache`. Cache keys are per-node signatures
/// ([`space::node_signature`]): op + input shape + producer-distance
/// topology, so chains share entries across models/positions while any
/// rewiring (skip edges, residual joins) re-keys and re-tunes.
pub fn tune_graph_shape(
    graph: &Graph,
    cfg: &McuConfig,
    objective: Objective,
    cache: &mut TuningCache,
) -> (TunedSchedule, TuneStats) {
    tune_graph_shape_backend(graph, cfg, objective, BackendSel::Scalar, cache)
}

/// [`tune_graph_shape`] under an explicit host-backend policy
/// ([`BackendSel`]): the policy filters each node's candidate list (see
/// [`node_candidates`]) and is folded into every cache key
/// ([`cache_key_backend`]), so schedules tuned under different policies
/// never replay each other's entries. The modeled MCU costs are
/// backend-invariant — policies change which host kernel deploys, never
/// the reported cycles/energy/RAM of a given (kernel, lowering).
///
/// This is the budget-∞ case of [`tune_graph_joint`] — per-node greedy
/// decisions, with per-layer RAM priced by the incremental liveness
/// model rather than the old input+output sum.
pub fn tune_graph_shape_backend(
    graph: &Graph,
    cfg: &McuConfig,
    objective: Objective,
    backend: BackendSel,
    cache: &mut TuningCache,
) -> (TunedSchedule, TuneStats) {
    let (sched, stats) = tune_graph_joint(graph, cfg, objective, backend, None, cache);
    (
        sched.expect("unbudgeted tuning always finds a schedule"),
        stats,
    )
}

/// Joint whole-graph schedule search under a hard RAM budget: a DP over
/// the topo order whose state is (node index, assignment so far,
/// incremental liveness peak), minimizing `objective` subject to
/// `peak working RAM ≤ ram_budget`, pruned by the incremental arena
/// planner ([`IncrementalPeak`], extended one value per step — see
/// [`act_step_peaks`]).
///
/// The search is **exact**, not a heuristic beam: activation intervals
/// are shape-derived and candidate-independent, so a node's working RAM
/// decomposes as `step_peak[i] + scratch(candidate)` where `step_peak`
/// is fixed by the graph alone. The budget constraint therefore tests
/// each candidate independently, cross-node state never interacts, and
/// the DP's beam collapses to width 1: the per-node admissible argmin IS
/// the global optimum. With `ram_budget = None` the admissible set is
/// the full space and the decisions are exactly the per-node greedy ones
/// ([`tune_graph_shape_backend`] delegates here).
///
/// Returns `None` when some node has *no* candidate that fits the
/// budget (the budget is below the graph's activation floor plus the
/// node's cheapest scratch). The per-node cache is consulted and filled
/// with **unconstrained** winners only — entries are keyed by node
/// signature, which carries no budget — and a cached winner is replayed
/// exactly when it still applies and fits; a fitting unconstrained
/// argmin is also the budgeted argmin (the minimum over a superset,
/// attained inside the subset).
pub fn tune_graph_joint(
    graph: &Graph,
    cfg: &McuConfig,
    objective: Objective,
    backend: BackendSel,
    ram_budget: Option<usize>,
    cache: &mut TuningCache,
) -> (Option<TunedSchedule>, TuneStats) {
    let mcu_fp = mcu_fingerprint(cfg);
    let obj_name = objective.name();
    let mut stats = TuneStats::default();
    let mut decisions: Vec<LayerDecision> = Vec::with_capacity(graph.nodes.len());
    // shapes, not tensors: nothing is executed
    let shapes = graph.value_shapes();
    let step_peaks = act_step_peaks(graph, &shapes);
    let budget = ram_budget.unwrap_or(usize::MAX);

    for (index, node) in graph.nodes.iter().enumerate() {
        let sig = space::node_signature(node, index, &shapes);
        let key = cache_key_backend(&sig, &mcu_fp, &obj_name, backend);

        // replay only candidates that still apply (a schema change in
        // the space enum would otherwise panic at execution time) AND
        // fit the budget at this step's liveness peak
        let replay = cache.get(&key).copied().filter(|e| {
            node_applies(node, &e.candidate)
                && step_peaks[index] + node_scratch_bytes(node, &e.candidate, &shapes) <= budget
        });
        let decision = match replay {
            Some(e) => {
                stats.cache_hits += 1;
                stats.candidates += 1;
                let mut d = decision_from_entry(index, node.op.name(), &e, true);
                d.ram_bytes = step_peaks[index] + node_scratch_bytes(node, &e.candidate, &shapes);
                d
            }
            None => {
                // two argmins in one scan: the unconstrained winner goes
                // to the cache, the budget-admissible winner deploys
                let mut best: Option<(f64, CacheEntry)> = None;
                let mut fit: Option<(f64, CacheEntry, usize)> = None;
                for cand in node_candidates(node, backend) {
                    let (entry, m) = score_node_candidate(node, &cand, &shapes, cfg);
                    let score = objective.score(
                        m.latency_s,
                        m.energy_mj,
                        entry.ram_bytes,
                        entry.flash_bytes,
                    );
                    stats.analytic += 1;
                    stats.candidates += 1;
                    if best.as_ref().map(|(s, _)| score < *s).unwrap_or(true) {
                        best = Some((score, entry));
                    }
                    let need = step_peaks[index] + node_scratch_bytes(node, &cand, &shapes);
                    if need <= budget
                        && fit.as_ref().map(|(s, _, _)| score < *s).unwrap_or(true)
                    {
                        fit = Some((score, entry, need));
                    }
                }
                let (_, entry) = best.expect("every node has at least one candidate");
                cache.put(key, entry);
                let Some((_, entry, need)) = fit else {
                    // budget infeasible at this node, whatever the rest
                    // of the graph does
                    return (None, stats);
                };
                let mut d = decision_from_entry(index, node.op.name(), &entry, false);
                d.ram_bytes = need;
                d
            }
        };
        decisions.push(decision);
    }

    let latency_s = decisions.iter().map(|d| d.latency_s).sum();
    let energy_mj = decisions.iter().map(|d| d.energy_mj).sum();
    let peak_ram_bytes = decisions.iter().map(|d| d.ram_bytes).max().unwrap_or(0);
    let flash_bytes = decisions.iter().map(|d| d.flash_bytes).sum();
    (
        Some(TunedSchedule {
            model: graph.name.clone(),
            mcu: mcu_fp,
            objective: obj_name,
            layers: decisions,
            latency_s,
            energy_mj,
            peak_ram_bytes,
            flash_bytes,
        }),
        stats,
    )
}

/// The full latency↔RAM Pareto frontier of a graph: every
/// non-dominated (peak working RAM, objective-optimal schedule) trade
/// the joint search can reach. Candidate budgets are the distinct
/// per-(node, candidate) RAM requirements — between two consecutive
/// requirements the admissible sets (and hence the optimal schedule)
/// cannot change, so this threshold sweep is exhaustive, not sampled.
/// Dominated points are eliminated and the rest ordered peak-ascending /
/// latency-descending by [`Frontier::new`].
///
/// Frontiers are cached wholesale under
/// `frontier|graph signature|MCU|objective|backend`
/// ([`space::graph_signature`] × [`mcu_fingerprint`] ×
/// [`Objective::name`] × [`BackendSel::as_str`]); a warm call replays
/// the frontier without re-scoring anything (reported as one cache hit
/// per node in [`TuneStats`]).
pub fn tune_graph_frontier(
    graph: &Graph,
    cfg: &McuConfig,
    objective: Objective,
    backend: BackendSel,
    cache: &mut TuningCache,
) -> (Frontier, TuneStats) {
    let mcu_fp = mcu_fingerprint(cfg);
    let obj_name = objective.name();
    let mut stats = TuneStats::default();
    let fkey = frontier_key(&space::graph_signature(graph), &mcu_fp, &obj_name, backend);
    if let Some(f) = cache.get_frontier(&fkey) {
        stats.cache_hits += graph.nodes.len();
        return (f.clone(), stats);
    }

    let shapes = graph.value_shapes();
    let step_peaks = act_step_peaks(graph, &shapes);
    // score every (node, candidate) pair once
    struct Scored {
        entry: CacheEntry,
        score: f64,
        need: usize,
    }
    let mut table: Vec<Vec<Scored>> = Vec::with_capacity(graph.nodes.len());
    for (index, node) in graph.nodes.iter().enumerate() {
        let mut row = Vec::new();
        for cand in node_candidates(node, backend) {
            let (entry, m) = score_node_candidate(node, &cand, &shapes, cfg);
            let score = objective.score(
                m.latency_s,
                m.energy_mj,
                entry.ram_bytes,
                entry.flash_bytes,
            );
            stats.analytic += 1;
            stats.candidates += 1;
            let need = step_peaks[index] + node_scratch_bytes(node, &cand, &shapes);
            row.push(Scored { entry, score, need });
        }
        table.push(row);
    }

    let mut thresholds: Vec<usize> = table.iter().flatten().map(|s| s.need).collect();
    thresholds.sort_unstable();
    thresholds.dedup();

    let mut points = Vec::new();
    'budgets: for &b in &thresholds {
        let mut cands = Vec::with_capacity(table.len());
        let (mut lat, mut en, mut peak, mut flash) = (0f64, 0f64, 0usize, 0usize);
        for row in &table {
            let mut best: Option<&Scored> = None;
            for s in row {
                if s.need <= b && best.map(|x| s.score < x.score).unwrap_or(true) {
                    best = Some(s);
                }
            }
            let Some(s) = best else { continue 'budgets };
            cands.push(s.entry.candidate);
            lat += s.entry.latency_s;
            en += s.entry.energy_mj;
            peak = peak.max(s.need);
            flash += s.entry.flash_bytes;
        }
        points.push(FrontierPoint {
            peak_ram_bytes: peak,
            latency_s: lat,
            energy_mj: en,
            flash_bytes: flash,
            candidates: cands,
        });
    }

    let frontier = Frontier::new(
        graph.name.clone(),
        mcu_fp,
        obj_name,
        backend.as_str().to_string(),
        points,
    );
    cache.put_frontier(fkey, frontier.clone());
    (frontier, stats)
}

/// Deployment-facing budget selection: compute (or replay) the graph's
/// latency↔RAM frontier and materialize the lowest-latency schedule
/// whose liveness peak fits `ram_budget`
/// ([`Frontier::cheapest_within`] → [`schedule_from_candidates`]).
/// Returns `None` when even the smallest frontier point exceeds the
/// budget — the caller decides whether that refuses deployment
/// (serving) or reports infeasibility (CLI).
pub fn tune_graph_budgeted(
    graph: &Graph,
    cfg: &McuConfig,
    objective: Objective,
    backend: BackendSel,
    ram_budget: usize,
    cache: &mut TuningCache,
) -> (Option<TunedSchedule>, TuneStats) {
    let (frontier, stats) = tune_graph_frontier(graph, cfg, objective, backend, cache);
    let sched = frontier
        .cheapest_within(ram_budget)
        .map(|p| schedule_from_candidates(graph, &p.candidates, cfg, objective));
    (sched, stats)
}

/// Materialize a [`TunedSchedule`] from an explicit per-node candidate
/// assignment (e.g. a [`FrontierPoint`] picked at deploy time): re-price
/// each node analytically and apply the liveness RAM model — the same
/// totals the joint search would report for this assignment. Panics if
/// a candidate does not apply to its node.
pub fn schedule_from_candidates(
    graph: &Graph,
    cands: &[Candidate],
    cfg: &McuConfig,
    objective: Objective,
) -> TunedSchedule {
    assert_eq!(cands.len(), graph.nodes.len(), "schedule/graph mismatch");
    let shapes = graph.value_shapes();
    let step_peaks = act_step_peaks(graph, &shapes);
    let mut decisions = Vec::with_capacity(cands.len());
    for (index, (node, cand)) in graph.nodes.iter().zip(cands).enumerate() {
        assert!(
            node_applies(node, cand),
            "candidate {cand:?} does not apply to node {index}"
        );
        let (entry, _) = score_node_candidate(node, cand, &shapes, cfg);
        let mut d = decision_from_entry(index, node.op.name(), &entry, false);
        d.ram_bytes = step_peaks[index] + node_scratch_bytes(node, cand, &shapes);
        decisions.push(d);
    }
    let latency_s = decisions.iter().map(|d| d.latency_s).sum();
    let energy_mj = decisions.iter().map(|d| d.energy_mj).sum();
    let peak_ram_bytes = decisions.iter().map(|d| d.ram_bytes).max().unwrap_or(0);
    let flash_bytes = decisions.iter().map(|d| d.flash_bytes).sum();
    TunedSchedule {
        model: graph.name.clone(),
        mcu: mcu_fingerprint(cfg),
        objective: objective.name(),
        layers: decisions,
        latency_s,
        energy_mj,
        peak_ram_bytes,
        flash_bytes,
    }
}

/// Per-layer SIMD-substitute flags for serving paths that only know the
/// global scalar/SIMD dichotomy: `true` where the tuned lowering is an
/// im2col/SIMD one.
pub fn simd_flags(schedule: &TunedSchedule) -> Vec<bool> {
    schedule
        .layers
        .iter()
        .map(|d| matches!(d.candidate.lowering, super::space::Lowering::Im2col { .. }))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytic::Primitive;
    use crate::harness::measure_model;
    use crate::models::{experiment_input, experiment_layer, mcunet, LayerParams};
    use crate::nn::{CountingMonitor, NoopMonitor};

    fn quick_layer() -> (Model, Tensor) {
        let p = LayerParams::new(2, 3, 8, 4, 4);
        (experiment_layer(&p, Primitive::Standard, 3), experiment_input(&p, 4))
    }

    #[test]
    fn analytic_search_matches_instrumented_oracle_decisions() {
        // The acceptance criterion: analytic scoring must reproduce the
        // pre-change simulator-scored search byte for byte. The oracle
        // below IS that search — execute every candidate under a counting
        // monitor, map through the cost model, keep the argmin.
        let cfg = McuConfig::default();
        for prim in Primitive::ALL {
            let p = LayerParams::new(2, 3, 8, 4, 4);
            let model = experiment_layer(&p, prim, 11);
            let x = experiment_input(&p, 12);
            let mut cache = TuningCache::in_memory();
            let (sched, stats) = tune_model(&model, &x, &cfg, Objective::Latency, &mut cache);
            assert_eq!(stats.evaluations, 0, "cold tune must not touch the simulator");
            assert!(stats.analytic > 0);

            let mut t = x.clone();
            for (layer, d) in model.layers.iter().zip(&sched.layers) {
                let in_shape = t.shape;
                let mut best: Option<(f64, Candidate, Measurement)> = None;
                for cand in space::candidates(layer) {
                    let mut mon = CountingMonitor::new();
                    space::execute(layer, &cand, &t, &mut mon);
                    let m = measure(&mon.counts, cand.lowering.path_class(), &cfg);
                    let ram = space::ram_bytes(layer, &cand, &in_shape);
                    let flash = space::flash_bytes(layer, &cand);
                    let score = Objective::Latency.score(m.latency_s, m.energy_mj, ram, flash);
                    if best.as_ref().map(|(s, _, _)| score < *s).unwrap_or(true) {
                        best = Some((score, cand, m));
                    }
                }
                let (_, cand, m) = best.expect("non-empty candidate space");
                assert_eq!(d.candidate, cand, "{prim:?}/{}", layer.name());
                // identical integer counts through identical arithmetic:
                // the costs must match bitwise, not just approximately
                assert_eq!(d.cycles, m.cycles, "{prim:?}/{}", layer.name());
                assert_eq!(d.latency_s, m.latency_s, "{prim:?}/{}", layer.name());
                assert_eq!(d.energy_mj, m.energy_mj, "{prim:?}/{}", layer.name());
                assert_eq!(d.mem_accesses, m.mem_accesses, "{prim:?}/{}", layer.name());
                assert_eq!(d.effective_macs, m.effective_macs, "{prim:?}/{}", layer.name());
                t = layer.forward(&t, false, &mut NoopMonitor);
            }
        }
    }

    #[test]
    fn node_oracle_covers_residual_joins_and_vec_twins_through_the_joint_path() {
        // The counts oracle, extended to the graph IR: every candidate
        // of every node — ResidualAdd and vec-backend twins included —
        // scores in closed form exactly what a counting monitor observes
        // executing it, on dense AND channel-pruned residual zoo models.
        // The pruned graphs matter: compaction rebuilds every layer with
        // fewer channels, and the closed-form counts must stay exact on
        // the compacted shapes, not just the hand-built ones.
        let cfg = McuConfig::default();
        let mut saw_add = false;
        let mut saw_vec = false;
        for prim in Primitive::ALL {
            for graph in [
                crate::models::mcunet_residual(prim, 42),
                crate::models::mcunet_residual_pruned(prim, 42, 0.5),
            ] {
                let shapes = graph.value_shapes();
                let mut values = vec![Tensor::zeros(graph.input_shape, graph.input_q)];
                crate::util::prng::Rng::new(17).fill_i8(&mut values[0].data, -96, 95);
                for node in &graph.nodes {
                    for cand in node_candidates(node, BackendSel::Auto) {
                        let mut mon = CountingMonitor::new();
                        let analytic = match &node.op {
                            NodeOp::Layer(l) => {
                                space::execute(l, &cand, &values[node.inputs[0]], &mut mon);
                                space::analytic_counts(l, &cand, &shapes[node.inputs[0]])
                            }
                            NodeOp::Add(a) => {
                                saw_add = true;
                                a.forward(
                                    &values[node.inputs[0]],
                                    &values[node.inputs[1]],
                                    &mut mon,
                                );
                                counts::residual_add_counts(&shapes[node.inputs[0]])
                            }
                        };
                        saw_vec |= cand.backend == Backend::VecLanes;
                        assert_eq!(
                            analytic,
                            mon.counts,
                            "{}/{}/{cand:?}",
                            graph.name,
                            node.op.name()
                        );
                        // and the cache entry the joint DP scores is the
                        // cost model applied to exactly those counts
                        let (entry, m) = score_node_candidate(node, &cand, &shapes, &cfg);
                        let want = measure(&analytic, cand.lowering.path_class(), &cfg);
                        assert_eq!(entry.cycles, want.cycles, "{}", graph.name);
                        assert_eq!(entry.effective_macs, want.effective_macs, "{}", graph.name);
                        assert_eq!(m.mem_accesses, want.mem_accesses, "{}", graph.name);
                    }
                    let out = match &node.op {
                        NodeOp::Layer(l) => {
                            l.forward(&values[node.inputs[0]], false, &mut NoopMonitor)
                        }
                        NodeOp::Add(a) => a.forward(
                            &values[node.inputs[0]],
                            &values[node.inputs[1]],
                            &mut NoopMonitor,
                        ),
                    };
                    values.push(out);
                }
                // through the joint tuner path: every winning decision's
                // counts-derived fields are reproduced by instrumenting
                // the chosen candidate
                let mut cache = TuningCache::in_memory();
                let (sched, _) = tune_graph_joint(
                    &graph,
                    &cfg,
                    Objective::Latency,
                    BackendSel::Auto,
                    None,
                    &mut cache,
                );
                let sched = sched.expect("unbudgeted joint search succeeds");
                for (node, d) in graph.nodes.iter().zip(&sched.layers) {
                    let mut mon = CountingMonitor::new();
                    match &node.op {
                        NodeOp::Layer(l) => {
                            space::execute(l, &d.candidate, &values[node.inputs[0]], &mut mon);
                        }
                        NodeOp::Add(a) => {
                            a.forward(&values[node.inputs[0]], &values[node.inputs[1]], &mut mon);
                        }
                    }
                    let m = measure(&mon.counts, d.candidate.lowering.path_class(), &cfg);
                    assert_eq!(d.cycles, m.cycles, "{}/{}", graph.name, node.op.name());
                    assert_eq!(
                        d.effective_macs,
                        m.effective_macs,
                        "{}/{}",
                        graph.name,
                        node.op.name()
                    );
                    assert_eq!(
                        d.mem_accesses,
                        m.mem_accesses,
                        "{}/{}",
                        graph.name,
                        node.op.name()
                    );
                }
            }
        }
        assert!(saw_add, "residual zoo contains no joins");
        assert!(saw_vec, "auto candidate spaces contained no vec twins");
    }

    #[test]
    fn tuned_run_is_bit_exact_with_model_forward() {
        let cfg = McuConfig::default();
        for prim in Primitive::ALL {
            let p = LayerParams::new(2, 3, 8, 4, 4);
            let model = experiment_layer(&p, prim, 3);
            let x = experiment_input(&p, 4);
            let mut cache = TuningCache::in_memory();
            let (sched, _) = tune_model(&model, &x, &cfg, Objective::Latency, &mut cache);
            let want = model.forward(&x, false, &mut NoopMonitor);
            let got = sched.run(&model, &x, &mut NoopMonitor);
            assert_eq!(want.data, got.data, "{prim:?}");
        }
    }

    #[test]
    fn tuned_latency_never_worse_than_fixed_paths() {
        let cfg = McuConfig::default();
        for prim in Primitive::ALL {
            let p = LayerParams::new(2, 3, 10, 8, 8);
            let model = experiment_layer(&p, prim, 7);
            let x = experiment_input(&p, 8);
            let mut cache = TuningCache::in_memory();
            let (sched, _) = tune_model(&model, &x, &cfg, Objective::Latency, &mut cache);
            let scalar = measure_model(&model, &x, false, &cfg);
            assert!(
                sched.latency_s <= scalar.latency_s + 1e-12,
                "{prim:?}: tuned {} > scalar {}",
                sched.latency_s,
                scalar.latency_s
            );
            if prim.has_simd() {
                let simd = measure_model(&model, &x, true, &cfg);
                assert!(
                    sched.latency_s <= simd.latency_s + 1e-12,
                    "{prim:?}: tuned {} > simd {}",
                    sched.latency_s,
                    simd.latency_s
                );
            }
        }
    }

    #[test]
    fn warm_cache_performs_zero_evaluations() {
        let cfg = McuConfig::default();
        let (model, x) = quick_layer();
        let mut cache = TuningCache::in_memory();
        let (cold, s1) = tune_model(&model, &x, &cfg, Objective::Latency, &mut cache);
        assert_eq!(s1.evaluations, 0, "analytic scoring never touches the simulator");
        assert!(s1.analytic > 0);
        assert_eq!(s1.cache_hits, 0);
        let (warm, s2) = tune_model(&model, &x, &cfg, Objective::Latency, &mut cache);
        assert_eq!(s2.evaluations, 0, "warm cache must not touch the simulator");
        assert_eq!(s2.analytic, 0, "warm cache must not score at all");
        assert_eq!(s2.cache_hits, model.layers.len());
        assert_eq!(cold.latency_s, warm.latency_s);
        assert_eq!(cold.layers.len(), warm.layers.len());
        for (a, b) in cold.layers.iter().zip(&warm.layers) {
            assert_eq!(a.candidate, b.candidate);
            assert!(b.from_cache);
        }
    }

    #[test]
    fn changing_mcu_or_objective_retunes() {
        let cfg = McuConfig::default();
        let (model, x) = quick_layer();
        let mut cache = TuningCache::in_memory();
        let (_, s1) = tune_model(&model, &x, &cfg, Objective::Latency, &mut cache);
        assert!(s1.analytic > 0);
        // same cache, different objective: misses
        let (_, s2) = tune_model(&model, &x, &cfg, Objective::Energy, &mut cache);
        assert!(s2.analytic > 0);
        // same cache, different MCU config: misses
        let o0 = McuConfig { freq_mhz: 84.0, opt: crate::mcu::OptLevel::O0 };
        let (_, s3) = tune_model(&model, &x, &o0, Objective::Latency, &mut cache);
        assert!(s3.analytic > 0);
        // and every combination is now warm
        let (_, w) = tune_model(&model, &x, &cfg, Objective::Energy, &mut cache);
        assert_eq!(w.analytic, 0);
        assert_eq!(w.evaluations, 0);
    }

    #[test]
    fn ram_objective_prefers_small_working_sets() {
        let cfg = McuConfig::default();
        let (model, x) = quick_layer();
        let mut cache = TuningCache::in_memory();
        let (ram_sched, _) = tune_model(&model, &x, &cfg, Objective::PeakRam, &mut cache);
        let (lat_sched, _) = tune_model(&model, &x, &cfg, Objective::Latency, &mut cache);
        assert!(ram_sched.peak_ram_bytes <= lat_sched.peak_ram_bytes);
    }

    #[test]
    fn whole_model_tuning_covers_every_layer() {
        let cfg = McuConfig::default();
        let model = mcunet(Primitive::DepthwiseSeparable, 5);
        let x = Tensor::zeros(model.input_shape, model.input_q);
        let mut cache = TuningCache::in_memory();
        let (sched, stats) = tune_model(&model, &x, &cfg, Objective::Latency, &mut cache);
        assert_eq!(sched.layers.len(), model.layers.len());
        assert_eq!(stats.evaluations, 0);
        assert!(stats.analytic >= model.layers.len());
        assert!(sched.latency_s > 0.0 && sched.energy_mj > 0.0);
        assert!(sched.peak_ram_bytes > 0);
        // schedule markdown renders a row per layer + header/totals
        let md = sched.to_markdown();
        assert_eq!(md.lines().count(), model.layers.len() + 5);
        // the flags view matches the decisions
        let flags = simd_flags(&sched);
        assert_eq!(flags.len(), model.layers.len());
    }

    #[test]
    fn backend_policies_pick_conforming_backends() {
        let cfg = McuConfig::default();
        let model = mcunet(Primitive::DepthwiseSeparable, 5);
        let mut cache = TuningCache::in_memory();
        let tune = |sel, cache: &mut TuningCache| {
            tune_model_shape_backend(&model, &cfg, Objective::Latency, sel, cache).0
        };
        let scalar = tune(BackendSel::Scalar, &mut cache);
        let vec_s = tune(BackendSel::Vec, &mut cache);
        let auto_s = tune(BackendSel::Auto, &mut cache);

        // the scalar policy IS the legacy entry point (same keys, same
        // space), so the pre-backend decisions are byte-stable
        let (legacy, legacy_stats) =
            tune_model_shape(&model, &cfg, Objective::Latency, &mut cache);
        assert_eq!(legacy_stats.cache_hits, model.layers.len());
        for (a, b) in scalar.layers.iter().zip(&legacy.layers) {
            assert_eq!(a.candidate, b.candidate);
        }
        for d in &scalar.layers {
            assert_eq!(d.candidate.backend, Backend::ScalarRef, "layer {}", d.index);
        }

        // vec policy: every node with vec twins (= every im2col-lowered
        // decision) deploys the vectorized kernel; direct-only nodes
        // keep the scalar reference
        assert!(
            vec_s.layers.iter().any(|d| d.candidate.backend == Backend::VecLanes),
            "the zoo model must tune at least one node onto the vec backend"
        );
        for d in &vec_s.layers {
            match d.candidate.lowering {
                Lowering::Im2col { .. } => {
                    assert_eq!(d.candidate.backend, Backend::VecLanes, "layer {}", d.index)
                }
                Lowering::Direct => {
                    assert_eq!(d.candidate.backend, Backend::ScalarRef, "layer {}", d.index)
                }
            }
        }

        // the modeled MCU stream is backend-invariant: auto reaches
        // exactly the scalar-optimal latency (per node, not just in
        // total) while deploying vec kernels on every tie; restricting
        // to vec-only candidates can only cost modeled latency
        assert_eq!(auto_s.latency_s, scalar.latency_s);
        assert!(vec_s.latency_s >= scalar.latency_s);
        for (a, s) in auto_s.layers.iter().zip(&scalar.layers) {
            assert_eq!(a.latency_s, s.latency_s, "layer {}", a.index);
            if matches!(a.candidate.lowering, Lowering::Im2col { .. }) {
                assert_eq!(a.candidate.backend, Backend::VecLanes, "layer {}", a.index);
            }
        }
    }

    #[test]
    fn vec_policy_graph_tune_is_bit_exact_and_replays_warm() {
        use crate::models::mcunet_residual;
        let cfg = McuConfig::default();
        let g = mcunet_residual(Primitive::DepthwiseSeparable, 5);
        let mut cache = TuningCache::in_memory();
        let (sched, cold) =
            tune_graph_shape_backend(&g, &cfg, Objective::Latency, BackendSel::Vec, &mut cache);
        assert_eq!(cold.evaluations, 0, "backend-aware tuning is analytic too");
        assert!(sched.layers.iter().any(|d| d.candidate.backend == Backend::VecLanes));

        // vec-backed compiled engine stays bit-exact with the scalar
        // reference executor on a residual graph
        let mut rng = crate::util::prng::Rng::new(9);
        let mut x = Tensor::zeros(g.input_shape, g.input_q);
        rng.fill_i8(&mut x.data, -64, 63);
        let want = g.forward(&x, true, &mut NoopMonitor);
        let mut ws = sched.workspace_graph(&g);
        let got = sched.run_in(&x, &mut ws, &mut NoopMonitor).clone();
        assert_eq!(want.data, got.data);

        // warm replay under the same policy hits every node; a
        // scalar-policy tune misses all of them (policy is in the key)
        let (_, warm) =
            tune_graph_shape_backend(&g, &cfg, Objective::Latency, BackendSel::Vec, &mut cache);
        assert_eq!(warm.cache_hits, g.nodes.len());
        assert_eq!(warm.analytic, 0);
        let (_, cross) = tune_graph_shape(&g, &cfg, Objective::Latency, &mut cache);
        assert_eq!(cross.cache_hits, 0, "scalar policy must not replay vec-policy entries");
        assert!(cross.analytic > 0);
    }

    #[test]
    fn residual_graph_tuning_covers_add_nodes_and_replays_warm() {
        use crate::models::mcunet_residual;
        let cfg = McuConfig::default();
        let g = mcunet_residual(Primitive::DepthwiseSeparable, 5);
        let mut cache = TuningCache::in_memory();
        let (sched, cold) = tune_graph_shape(&g, &cfg, Objective::Latency, &mut cache);
        assert_eq!(sched.layers.len(), g.nodes.len());
        assert_eq!(cold.evaluations, 0, "graph tuning is analytic too");
        assert!(cold.analytic > 0);
        // residual joins tuned to their only (scalar) implementation,
        // with RAM charging both operands + the output
        let adds: Vec<_> = sched.layers.iter().filter(|d| d.layer == "add").collect();
        assert!(!adds.is_empty(), "residual model must contain add joins");
        for d in &adds {
            assert_eq!(d.candidate.kernel, KernelImpl::AsIs);
            assert_eq!(d.candidate.lowering, Lowering::Direct);
            assert!(d.ram_bytes > 0 && d.latency_s > 0.0);
        }
        // bit-exact: tuned reference executor vs the default engine path
        let mut rng = crate::util::prng::Rng::new(4);
        let mut x = Tensor::zeros(g.input_shape, g.input_q);
        rng.fill_i8(&mut x.data, -64, 63);
        let want = g.forward(&x, true, &mut NoopMonitor);
        let got = sched.run_graph(&g, &x, &mut NoopMonitor);
        assert_eq!(want.data, got.data);
        // and through the compiled engine from a bound arena
        let mut ws = sched.workspace_graph(&g);
        let got2 = sched.run_in(&x, &mut ws, &mut NoopMonitor).clone();
        assert_eq!(want.data, got2.data);
        // warm replay: the per-node cache keys (topology included) hit
        let (_, warm) = tune_graph_shape(&g, &cfg, Objective::Latency, &mut cache);
        assert_eq!(warm.analytic, 0, "warm graph tune must not re-score");
        assert_eq!(warm.evaluations, 0);
        assert_eq!(warm.cache_hits, g.nodes.len());
    }
}
