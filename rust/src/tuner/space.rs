//! The per-layer schedule space: which kernel implementations can compute
//! a layer (primitive substitution, where mathematically admissible),
//! which lowering each implementation admits (direct scalar loops vs
//! im2col + blocked SIMD matmul), and which (P, F) register blockings fit
//! the Cortex-M4 register file ([`crate::nn::blocking`]).
//!
//! Admissible substitutions (bit-exact by construction, asserted in
//! tests):
//! * a convolution with `G == Cx == Cy` IS a depthwise convolution
//!   (NNoM ships a dedicated kernel for that case — the tuner decides
//!   per-shape which one actually wins on the simulated MCU);
//! * a depthwise layer can conversely run through the grouped-conv
//!   kernel with `G == C`, which unlocks the generalized (P, F) blocked
//!   im2col lowering depthwise's own SIMD path does not have;
//! * a `1×1, G == 1` convolution IS a shift convolution with all-zero
//!   shifts (the Eq. 2 pointwise stage), letting the tuner price the
//!   shift-conv im2col gather against the standard widening fill.
//!
//! Everything else (add-convolution, batch-norm, activations, pooling)
//! only has its scalar implementation (§3.3: no SIMD add-convolution).

use crate::mcu::PathClass;
use crate::nn::blocking::fits_register_file;
use crate::nn::counts;
use crate::nn::{
    uniform_shifts, Backend, Layer, Monitor, Node, NodeOp, OpCounts, QuantConv, QuantDepthwise,
    Shape, ShiftConv, Tensor,
};

/// Which kernel implementation computes the layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelImpl {
    /// Execute the layer's own kernel.
    AsIs,
    /// Run a `G == Cx == Cy` convolution through the depthwise kernel.
    ConvAsDepthwise,
    /// Run a depthwise layer through the grouped-conv kernel (`G == C`).
    DepthwiseAsConv,
    /// Run a `1×1, G == 1` convolution through the shift-conv kernel
    /// (all-zero shifts).
    PointwiseAsShift,
}

impl KernelImpl {
    pub fn as_str(&self) -> &'static str {
        match self {
            KernelImpl::AsIs => "as-is",
            KernelImpl::ConvAsDepthwise => "conv-as-depthwise",
            KernelImpl::DepthwiseAsConv => "depthwise-as-conv",
            KernelImpl::PointwiseAsShift => "pointwise-as-shift",
        }
    }

    pub fn parse(s: &str) -> Result<KernelImpl, String> {
        match s {
            "as-is" => Ok(KernelImpl::AsIs),
            "conv-as-depthwise" => Ok(KernelImpl::ConvAsDepthwise),
            "depthwise-as-conv" => Ok(KernelImpl::DepthwiseAsConv),
            "pointwise-as-shift" => Ok(KernelImpl::PointwiseAsShift),
            other => Err(format!("unknown kernel impl {other:?}")),
        }
    }
}

/// How the chosen kernel is lowered.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Lowering {
    /// Direct scalar loops (the NNoM `local_*_q7` path).
    Direct,
    /// im2col + `__SMLAD` matmul, blocked at `patches × filters`
    /// (CMSIS-NN's design point is 2×2; the generalized blocking runs
    /// through [`crate::nn::blocking::mat_mult_block`]).
    Im2col { patches: usize, filters: usize },
}

impl Lowering {
    pub fn as_str(&self) -> String {
        match self {
            Lowering::Direct => "direct".to_string(),
            Lowering::Im2col { patches, filters } => format!("im2col{patches}x{filters}"),
        }
    }

    pub fn path_class(&self) -> PathClass {
        match self {
            Lowering::Direct => PathClass::Scalar,
            Lowering::Im2col { .. } => PathClass::Simd,
        }
    }
}

/// One point of the schedule space.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Candidate {
    pub kernel: KernelImpl,
    pub lowering: Lowering,
    /// Host execution backend for the compiled kernel. Orthogonal to the
    /// modeled MCU stream: a `VecLanes` candidate scores identically to
    /// its `ScalarRef` twin (events are a function of kernel × lowering
    /// only) and is admissible exactly where the lowering is `Im2col` —
    /// the vectorized hot loops are the im2col matmul family, the
    /// depthwise channel-lane kernel and the dense row-pair kernel.
    pub backend: Backend,
}

/// All (P, F) blockings that fit the M4 register file, P and F up to 4
/// (beyond that the register demand always spills).
pub fn blocking_options() -> Vec<(usize, usize)> {
    let mut v = Vec::new();
    for p in 1..=4usize {
        for f in 1..=4usize {
            if fits_register_file(p, f) {
                v.push((p, f));
            }
        }
    }
    v
}

/// The CMSIS-NN design point, the only blocking the fixed-function SIMD
/// kernels (shift / depthwise / dense pairing) implement.
pub const DESIGN_POINT: (usize, usize) = (2, 2);

fn conv_is_depthwise_shaped(c: &QuantConv) -> bool {
    c.groups == c.in_channels && c.groups == c.out_channels && c.groups > 0
}

fn conv_is_pointwise(c: &QuantConv) -> bool {
    c.kernel == 1 && c.groups == 1 && c.pad == 0
}

/// Enumerate the legal schedule space of one layer.
pub fn candidates(layer: &Layer) -> Vec<Candidate> {
    let mut out = Vec::new();
    // ScalarRef is pushed before its VecLanes twin so that, under the
    // search's first-strict-less argmin, analytic ties keep resolving to
    // the scalar reference (the default-policy decisions are unchanged
    // by the backend axis).
    let push = |out: &mut Vec<Candidate>, kernel: KernelImpl, lowering: Lowering| {
        out.push(Candidate { kernel, lowering, backend: Backend::ScalarRef });
        if matches!(lowering, Lowering::Im2col { .. }) {
            out.push(Candidate { kernel, lowering, backend: Backend::VecLanes });
        }
    };
    match layer {
        Layer::Conv(c) => {
            push(&mut out, KernelImpl::AsIs, Lowering::Direct);
            for (p, f) in blocking_options() {
                push(&mut out, KernelImpl::AsIs, Lowering::Im2col { patches: p, filters: f });
            }
            if conv_is_depthwise_shaped(c) {
                push(&mut out, KernelImpl::ConvAsDepthwise, Lowering::Direct);
                push(
                    &mut out,
                    KernelImpl::ConvAsDepthwise,
                    Lowering::Im2col { patches: DESIGN_POINT.0, filters: DESIGN_POINT.1 },
                );
            }
            if conv_is_pointwise(c) {
                push(&mut out, KernelImpl::PointwiseAsShift, Lowering::Direct);
                push(
                    &mut out,
                    KernelImpl::PointwiseAsShift,
                    Lowering::Im2col { patches: DESIGN_POINT.0, filters: DESIGN_POINT.1 },
                );
            }
        }
        Layer::Depthwise(_) => {
            push(&mut out, KernelImpl::AsIs, Lowering::Direct);
            push(
                &mut out,
                KernelImpl::AsIs,
                Lowering::Im2col { patches: DESIGN_POINT.0, filters: DESIGN_POINT.1 },
            );
            push(&mut out, KernelImpl::DepthwiseAsConv, Lowering::Direct);
            for (p, f) in blocking_options() {
                push(
                    &mut out,
                    KernelImpl::DepthwiseAsConv,
                    Lowering::Im2col { patches: p, filters: f },
                );
            }
        }
        Layer::Shift(_) => {
            push(&mut out, KernelImpl::AsIs, Lowering::Direct);
            push(
                &mut out,
                KernelImpl::AsIs,
                Lowering::Im2col { patches: DESIGN_POINT.0, filters: DESIGN_POINT.1 },
            );
        }
        Layer::Dense(_) => {
            push(&mut out, KernelImpl::AsIs, Lowering::Direct);
            // the CMSIS fully-connected kernel widens one input column and
            // consumes 2 weight rows per step
            push(&mut out, KernelImpl::AsIs, Lowering::Im2col { patches: 1, filters: 2 });
        }
        // scalar-only layers (§3.3: no SIMD add-convolution; BN and the
        // glue layers have no distinct SIMD implementation)
        _ => push(&mut out, KernelImpl::AsIs, Lowering::Direct),
    }
    out
}

/// Whether a (P, F) blocking is one the space enumerates: both in 1..=4
/// and within the register file (mirrors [`blocking_options`]).
fn legal_blocking(p: usize, f: usize) -> bool {
    (1..=4).contains(&p) && (1..=4).contains(&f) && fits_register_file(p, f)
}

/// Whether (kernel, lowering) legally applies to `layer` (used when
/// replaying cached schedules against a possibly-changed model). O(1) —
/// the warm-cache replay path runs this per layer, so it must not
/// enumerate the space; equivalence with `candidates(layer).contains`
/// is pinned by a test below.
pub fn applies(layer: &Layer, cand: &Candidate) -> bool {
    // the vec backend only exists for the im2col-lowered hot kernels;
    // Direct loops are scalar-only on every layer kind
    if cand.backend == Backend::VecLanes && !matches!(cand.lowering, Lowering::Im2col { .. }) {
        return false;
    }
    match (layer, cand.kernel, cand.lowering) {
        (Layer::Conv(_), KernelImpl::AsIs, Lowering::Direct) => true,
        (Layer::Conv(_), KernelImpl::AsIs, Lowering::Im2col { patches, filters }) => {
            legal_blocking(patches, filters)
        }
        (Layer::Conv(c), KernelImpl::ConvAsDepthwise, Lowering::Direct) => {
            conv_is_depthwise_shaped(c)
        }
        (Layer::Conv(c), KernelImpl::ConvAsDepthwise, Lowering::Im2col { patches, filters }) => {
            conv_is_depthwise_shaped(c) && (patches, filters) == DESIGN_POINT
        }
        (Layer::Conv(c), KernelImpl::PointwiseAsShift, Lowering::Direct) => conv_is_pointwise(c),
        (Layer::Conv(c), KernelImpl::PointwiseAsShift, Lowering::Im2col { patches, filters }) => {
            conv_is_pointwise(c) && (patches, filters) == DESIGN_POINT
        }
        (Layer::Depthwise(_), KernelImpl::AsIs, Lowering::Direct) => true,
        (Layer::Depthwise(_), KernelImpl::AsIs, Lowering::Im2col { patches, filters }) => {
            (patches, filters) == DESIGN_POINT
        }
        (Layer::Depthwise(_), KernelImpl::DepthwiseAsConv, Lowering::Direct) => true,
        (Layer::Depthwise(_), KernelImpl::DepthwiseAsConv, Lowering::Im2col { patches, filters }) => {
            legal_blocking(patches, filters)
        }
        (Layer::Shift(_), KernelImpl::AsIs, Lowering::Direct) => true,
        (Layer::Shift(_), KernelImpl::AsIs, Lowering::Im2col { patches, filters }) => {
            (patches, filters) == DESIGN_POINT
        }
        (Layer::Dense(_), KernelImpl::AsIs, Lowering::Direct) => true,
        (Layer::Dense(_), KernelImpl::AsIs, Lowering::Im2col { patches, filters }) => {
            (patches, filters) == (1, 2)
        }
        // glue layers: scalar only
        (_, KernelImpl::AsIs, Lowering::Direct) => true,
        _ => false,
    }
}

/// Reinterpret a depthwise-shaped convolution as the depthwise kernel.
pub(crate) fn conv_to_depthwise(c: &QuantConv) -> QuantDepthwise {
    debug_assert!(conv_is_depthwise_shaped(c));
    QuantDepthwise {
        kernel: c.kernel,
        channels: c.in_channels,
        pad: c.pad,
        // [C][k][k][1] row-major IS [C][k][k]
        weights: c.weights.clone(),
        bias: c.bias.clone(),
        q_in: c.q_in,
        q_w: c.q_w,
        q_out: c.q_out,
    }
}

/// Reinterpret a depthwise layer as a grouped convolution with `G == C`.
pub(crate) fn depthwise_to_conv(d: &QuantDepthwise) -> QuantConv {
    QuantConv {
        kernel: d.kernel,
        groups: d.channels,
        in_channels: d.channels,
        out_channels: d.channels,
        pad: d.pad,
        weights: d.weights.clone(),
        bias: d.bias.clone(),
        q_in: d.q_in,
        q_w: d.q_w,
        q_out: d.q_out,
    }
}

/// Reinterpret a `1×1, G == 1` convolution as a zero-shift shift conv.
pub(crate) fn pointwise_to_shift(c: &QuantConv) -> ShiftConv {
    debug_assert!(conv_is_pointwise(c));
    ShiftConv {
        in_channels: c.in_channels,
        out_channels: c.out_channels,
        shifts: uniform_shifts(c.in_channels, 1), // all (0, 0)
        // conv [Cy][1][1][Cx] row-major IS pointwise [Cy][Cx]
        weights: c.weights.clone(),
        bias: c.bias.clone(),
        q_in: c.q_in,
        q_w: c.q_w,
        q_out: c.q_out,
    }
}

/// Generalized blocked im2col convolution: fill `p_blk` q15 columns, feed
/// `f_blk` weight rows at a time through
/// [`mat_mult_block`](crate::nn::blocking::mat_mult_block), requantize.
/// At the 2×2 design point this is event- and result-equivalent to
/// [`QuantConv::forward_simd`] (tested); other blockings explore the §3.3
/// trade between register-file reuse and im2col buffer size.
///
/// Allocating wrapper over the engine's single blocked-convolution core
/// ([`crate::nn::plan::conv_blocked_into`]) — the compiled `ExecPlan`
/// path runs the same code with workspace-resident scratch.
pub fn conv_im2col_blocked<M: Monitor>(
    conv: &QuantConv,
    x: &Tensor,
    p_blk: usize,
    f_blk: usize,
    mon: &mut M,
) -> Tensor {
    assert!(p_blk >= 1 && f_blk >= 1, "degenerate blocking");
    conv.validate(&x.shape).expect("invalid conv configuration");
    let mut y = Tensor::zeros(conv.output_shape(&x.shape), conv.q_out);
    let klen = conv.kernel * conv.kernel * conv.ch_per_group();
    let mut cols = vec![0i16; p_blk * klen];
    let mut acc = vec![0i32; p_blk * f_blk];
    crate::nn::plan::conv_blocked_into(conv, x, &mut y, p_blk, f_blk, &mut cols, &mut acc, mon);
    y
}

/// Execute `layer` under a schedule-space candidate. Panics if the
/// candidate does not apply to the layer kind (callers enumerate via
/// [`candidates`] or validate via [`applies`]).
///
/// The candidate's [`Backend`] is deliberately ignored here: this is the
/// allocating *reference* executor, and the vec backend is pinned
/// bit-exact and event-stream-identical to it (in [`crate::nn::vec`]
/// unit properties and across the whole space via the compiled-plan
/// equivalence tests in [`crate::nn::plan`]), so the scalar reference is
/// the oracle for both backends.
pub fn execute<M: Monitor>(layer: &Layer, cand: &Candidate, x: &Tensor, mon: &mut M) -> Tensor {
    match (layer, cand.kernel) {
        (Layer::Conv(c), KernelImpl::AsIs) => match cand.lowering {
            Lowering::Direct => c.forward_scalar(x, mon),
            Lowering::Im2col { patches, filters } => {
                conv_im2col_blocked(c, x, patches, filters, mon)
            }
        },
        (Layer::Conv(c), KernelImpl::ConvAsDepthwise) => {
            let d = conv_to_depthwise(c);
            match cand.lowering {
                Lowering::Direct => d.forward_scalar(x, mon),
                Lowering::Im2col { .. } => d.forward_simd(x, mon),
            }
        }
        (Layer::Conv(c), KernelImpl::PointwiseAsShift) => {
            let s = pointwise_to_shift(c);
            match cand.lowering {
                Lowering::Direct => s.forward_scalar(x, mon),
                Lowering::Im2col { .. } => s.forward_simd(x, mon),
            }
        }
        (Layer::Depthwise(d), KernelImpl::AsIs) => match cand.lowering {
            Lowering::Direct => d.forward_scalar(x, mon),
            Lowering::Im2col { .. } => d.forward_simd(x, mon),
        },
        (Layer::Depthwise(d), KernelImpl::DepthwiseAsConv) => {
            let c = depthwise_to_conv(d);
            match cand.lowering {
                Lowering::Direct => c.forward_scalar(x, mon),
                Lowering::Im2col { patches, filters } => {
                    conv_im2col_blocked(&c, x, patches, filters, mon)
                }
            }
        }
        (Layer::Shift(s), KernelImpl::AsIs) => match cand.lowering {
            Lowering::Direct => s.forward_scalar(x, mon),
            Lowering::Im2col { .. } => s.forward_simd(x, mon),
        },
        (Layer::Dense(_), KernelImpl::AsIs) => match cand.lowering {
            Lowering::Direct => layer.forward(x, false, mon),
            Lowering::Im2col { .. } => layer.forward(x, true, mon),
        },
        (_, KernelImpl::AsIs) => {
            debug_assert_eq!(cand.lowering, Lowering::Direct);
            layer.forward(x, false, mon)
        }
        (l, k) => panic!("candidate {k:?} does not apply to layer {:?}", l.name()),
    }
}

/// Analytic [`OpCounts`] for `layer` executed under a schedule-space
/// candidate — exactly what [`execute`] emits into a `CountingMonitor`,
/// derived in closed form from shapes by [`crate::nn::counts`]. This is
/// what lets the search score the whole space with shape arithmetic
/// instead of instrumented forwards (the equality is property-tested
/// below across every candidate of every layer kind). Panics like
/// [`execute`] if the candidate does not apply. Like [`execute`], the
/// backend axis does not enter: the modeled MCU stream is a function of
/// kernel × lowering only.
pub fn analytic_counts(layer: &Layer, cand: &Candidate, in_shape: &Shape) -> OpCounts {
    match (layer, cand.kernel) {
        (Layer::Conv(c), KernelImpl::AsIs) => match cand.lowering {
            Lowering::Direct => counts::conv_scalar_counts(
                c.kernel, c.groups, c.in_channels, c.out_channels, c.pad, in_shape,
            ),
            Lowering::Im2col { patches, filters } => counts::conv_im2col_counts(
                c.kernel, c.groups, c.in_channels, c.out_channels, c.pad, in_shape, patches,
                filters,
            ),
        },
        (Layer::Conv(c), KernelImpl::ConvAsDepthwise) => match cand.lowering {
            Lowering::Direct => {
                counts::depthwise_scalar_counts(c.kernel, c.in_channels, c.pad, in_shape)
            }
            Lowering::Im2col { .. } => {
                counts::depthwise_simd_counts(c.kernel, c.in_channels, c.pad, in_shape)
            }
        },
        (Layer::Conv(c), KernelImpl::PointwiseAsShift) => {
            // the substituted shift table is all-zero: every gather lands
            // in bounds
            let zero_shifts = vec![(0i8, 0i8); c.in_channels];
            match cand.lowering {
                Lowering::Direct => {
                    counts::shift_scalar_counts(&zero_shifts, c.out_channels, in_shape)
                }
                Lowering::Im2col { .. } => {
                    counts::shift_simd_counts(&zero_shifts, c.out_channels, in_shape)
                }
            }
        }
        (Layer::Depthwise(d), KernelImpl::AsIs) => match cand.lowering {
            Lowering::Direct => {
                counts::depthwise_scalar_counts(d.kernel, d.channels, d.pad, in_shape)
            }
            Lowering::Im2col { .. } => {
                counts::depthwise_simd_counts(d.kernel, d.channels, d.pad, in_shape)
            }
        },
        (Layer::Depthwise(d), KernelImpl::DepthwiseAsConv) => match cand.lowering {
            Lowering::Direct => counts::conv_scalar_counts(
                d.kernel, d.channels, d.channels, d.channels, d.pad, in_shape,
            ),
            Lowering::Im2col { patches, filters } => counts::conv_im2col_counts(
                d.kernel, d.channels, d.channels, d.channels, d.pad, in_shape, patches, filters,
            ),
        },
        (Layer::Shift(s), KernelImpl::AsIs) => match cand.lowering {
            Lowering::Direct => counts::shift_scalar_counts(&s.shifts, s.out_channels, in_shape),
            Lowering::Im2col { .. } => {
                counts::shift_simd_counts(&s.shifts, s.out_channels, in_shape)
            }
        },
        (Layer::Dense(d), KernelImpl::AsIs) => match cand.lowering {
            Lowering::Direct => counts::dense_scalar_counts(d.in_features, d.out_features),
            Lowering::Im2col { .. } => counts::dense_simd_counts(d.in_features, d.out_features),
        },
        (l, KernelImpl::AsIs) => {
            debug_assert_eq!(cand.lowering, Lowering::Direct);
            counts::layer_counts(l, in_shape, false)
        }
        (l, k) => panic!("candidate {k:?} does not apply to layer {:?}", l.name()),
    }
}

/// SRAM scratch a candidate needs beyond the liveness-planned
/// activation arena: the q15 im2col buffer (P columns), the widened
/// dense input, or the shift-conv scalar path's materialized
/// intermediate map.
pub fn scratch_bytes(layer: &Layer, cand: &Candidate, in_shape: &Shape) -> usize {
    match (layer, cand.lowering) {
        // the shift-conv scalar path materializes the shifted intermediate
        // map I (Eq. 2) — same cost whether the layer is a native shift
        // conv or a pointwise conv substituted onto the shift kernel
        (Layer::Conv(_), Lowering::Direct) if cand.kernel == KernelImpl::PointwiseAsShift => {
            in_shape.len()
        }
        (Layer::Conv(c), Lowering::Im2col { patches, .. }) => match cand.kernel {
            // the shift gather column is 1×1×Cx
            KernelImpl::PointwiseAsShift => patches * c.in_channels * 2,
            // depthwise SIMD works in-register, no column buffer — but
            // the host-vectorized twin keeps a per-channel i32
            // accumulator strip in the workspace arena
            KernelImpl::ConvAsDepthwise if cand.backend == Backend::VecLanes => {
                4 * c.in_channels
            }
            KernelImpl::ConvAsDepthwise => 0,
            _ => patches * c.kernel * c.kernel * c.ch_per_group() * 2,
        },
        (Layer::Depthwise(d), Lowering::Im2col { patches, .. }) => match cand.kernel {
            KernelImpl::DepthwiseAsConv => patches * d.kernel * d.kernel * 2,
            // vec backend: per-channel i32 accumulator strip (see above)
            _ if cand.backend == Backend::VecLanes => 4 * d.channels,
            _ => 0,
        },
        (Layer::Shift(s), Lowering::Im2col { patches, .. }) => patches * s.in_channels * 2,
        (Layer::Shift(_), Lowering::Direct) => in_shape.len(), // intermediate map I
        (Layer::Dense(d), Lowering::Im2col { .. }) => d.in_features * 2,
        _ => 0,
    }
}

/// Peak working RAM of the layer under a candidate: input + output
/// activations plus candidate scratch.
pub fn ram_bytes(layer: &Layer, cand: &Candidate, in_shape: &Shape) -> usize {
    in_shape.len() + layer.output_shape(in_shape).len() + scratch_bytes(layer, cand, in_shape)
}

/// Flash footprint of one deployed candidate: the weight bytes the
/// chosen kernel stores (weights + bias + per-channel tables), exact and
/// closed-form like every other cost here. Kernel substitutions that
/// re-layout the parameters keep the byte count (`ConvAsDepthwise`,
/// `DepthwiseAsConv`); `PointwiseAsShift` materializes the per-channel
/// `(α, β)` shift table the source conv does not carry — 2 bytes per
/// input channel, exactly what [`Layer::Shift`] is billed for in
/// `Graph::weight_bytes`. For pruned graphs the layer is already
/// compacted, so this *is* the post-compaction footprint.
pub fn flash_bytes(layer: &Layer, cand: &Candidate) -> usize {
    let base = crate::nn::graph::layer_weight_bytes(layer);
    match (cand.kernel, layer) {
        (KernelImpl::PointwiseAsShift, Layer::Conv(c)) => base + 2 * c.in_channels,
        _ => base,
    }
}

/// [`flash_bytes`] for graph nodes: residual joins hold no parameters.
pub fn node_flash_bytes(node: &Node, cand: &Candidate) -> usize {
    match &node.op {
        NodeOp::Layer(l) => flash_bytes(l, cand),
        NodeOp::Add(_) => 0,
    }
}

/// A structural fingerprint of (layer, input shape): two layers with equal
/// signatures produce identical micro-op streams under every candidate,
/// so tuning results are shareable through the cache. Weight *values*
/// never affect event counts; shift *tables* do (border clipping), so the
/// shift assignment is folded in.
pub fn layer_signature(layer: &Layer, in_shape: &Shape) -> String {
    let shape = format!("{}x{}x{}", in_shape.h, in_shape.w, in_shape.c);
    match layer {
        Layer::Conv(c) => format!(
            "conv[g{},k{},ci{},co{},p{},q{}/{}/{}]@{shape}",
            c.groups,
            c.kernel,
            c.in_channels,
            c.out_channels,
            c.pad,
            c.q_in.frac_bits,
            c.q_w.frac_bits,
            c.q_out.frac_bits
        ),
        Layer::Depthwise(d) => format!(
            "dw[k{},c{},p{},q{}/{}/{}]@{shape}",
            d.kernel, d.channels, d.pad, d.q_in.frac_bits, d.q_w.frac_bits, d.q_out.frac_bits
        ),
        Layer::Shift(s) => {
            // fold the shift table into the signature (it changes border
            // clipping and therefore the counted events)
            let mut h = crate::util::fnv::Fnv1a::new();
            for &(a, b) in &s.shifts {
                h.byte(a as u8);
                h.byte(b as u8);
            }
            format!(
                "shift[ci{},co{},t{:016x},q{}/{}/{}]@{shape}",
                s.in_channels,
                s.out_channels,
                h.finish(),
                s.q_in.frac_bits,
                s.q_w.frac_bits,
                s.q_out.frac_bits
            )
        }
        Layer::AddConv(a) => format!(
            "add[k{},ci{},co{},p{},q{}/{}/{}]@{shape}",
            a.kernel,
            a.in_channels,
            a.out_channels,
            a.pad,
            a.q_in.frac_bits,
            a.q_w.frac_bits,
            a.q_out.frac_bits
        ),
        Layer::Bn(b) => format!("bn[c{},s{}]@{shape}", b.channels, b.out_shift()),
        Layer::Relu => format!("relu@{shape}"),
        Layer::MaxPool2 => format!("maxpool2@{shape}"),
        Layer::GlobalAvgPool(q) => format!(
            "gavg[{}]@{shape}",
            q.map(|p| p.frac_bits.to_string()).unwrap_or_else(|| "-".into())
        ),
        Layer::Dense(d) => format!(
            "dense[i{},o{},q{}/{}/{}]@{shape}",
            d.in_features, d.out_features, d.q_in.frac_bits, d.q_w.frac_bits, d.q_out.frac_bits
        ),
    }
}

/// [`layer_signature`] for graph nodes: the op signature plus the node's
/// input *topology* — the producer distance of every operand (how many
/// steps back each consumed value was defined; 1 everywhere on a linear
/// chain). Two structurally identical ops wired differently (a skip
/// edge, fan-out, a residual join) therefore key differently in the
/// tuning cache, so a linear schedule is never silently replayed onto a
/// rewired graph — while chains keep sharing entries across models and
/// positions exactly as before (the suffix is position-relative).
pub fn node_signature(node: &Node, index: usize, value_shapes: &[Shape]) -> String {
    let topo: Vec<String> = node
        .inputs
        .iter()
        .map(|&v| (index + 1 - v).to_string())
        .collect();
    let topo = topo.join(",");
    match &node.op {
        NodeOp::Layer(l) => {
            format!("{}~in{topo}", layer_signature(l, &value_shapes[node.inputs[0]]))
        }
        NodeOp::Add(a) => {
            let s = value_shapes[node.inputs[0]];
            format!(
                "resadd[q{}]@{}x{}x{}~in{topo}",
                a.q_out.frac_bits, s.h, s.w, s.c
            )
        }
    }
}

/// A whole-graph structural fingerprint: FNV-1a over every node's
/// [`node_signature`] (which already folds op kind, shapes, quantization
/// and input topology) plus the node count. Two graphs with equal
/// signatures present byte-identical tuning problems under every
/// candidate, so a Pareto frontier cached under this signature
/// ([`crate::tuner::cache::frontier_key`]) replays wholesale; any
/// rewiring or reshape changes some node signature and re-keys.
pub fn graph_signature(graph: &crate::nn::Graph) -> String {
    let shapes = graph.value_shapes();
    let mut h = crate::util::fnv::Fnv1a::new();
    for (index, node) in graph.nodes.iter().enumerate() {
        for b in node_signature(node, index, &shapes).bytes() {
            h.byte(b);
        }
        h.byte(b'\n'); // node separator: "ab"+"c" must differ from "a"+"bc"
    }
    format!("g{:016x}x{}", h.finish(), graph.nodes.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{CountingMonitor, NoopMonitor};
    use crate::quant::QParam;
    use crate::util::prng::Rng;
    use crate::util::prop::{check, ensure_eq_i8};

    fn random_conv(rng: &mut Rng, groups: usize, k: usize, cin: usize, cout: usize) -> QuantConv {
        let cpg = cin / groups;
        let mut weights = vec![0i8; cout * k * k * cpg];
        rng.fill_i8(&mut weights, -12, 12);
        QuantConv {
            kernel: k,
            groups,
            in_channels: cin,
            out_channels: cout,
            pad: k / 2,
            weights,
            bias: (0..cout).map(|_| rng.range(0, 64) as i32 - 32).collect(),
            q_in: QParam::new(7),
            q_w: QParam::new(7),
            q_out: QParam::new(5),
        }
    }

    fn random_input(rng: &mut Rng, h: usize, c: usize) -> Tensor {
        let mut t = Tensor::zeros(Shape::new(h, h, c), QParam::new(7));
        rng.fill_i8(&mut t.data, -16, 16);
        t
    }

    #[test]
    fn blocking_options_contain_design_point_and_fit() {
        let opts = blocking_options();
        assert!(opts.contains(&DESIGN_POINT));
        for &(p, f) in &opts {
            assert!(fits_register_file(p, f), "({p},{f})");
        }
        // the spilling squares are excluded
        assert!(!opts.contains(&(3, 3)));
        assert!(!opts.contains(&(4, 4)));
    }

    #[test]
    fn blocked_conv_at_design_point_is_event_equivalent_to_simd_path() {
        // The load-bearing equivalence for the tuner's acceptance
        // criterion: scoring candidate im2col(2,2) must reproduce the
        // sweep harness's SIMD measurement exactly.
        check(
            "blocked-conv-2x2-event-parity",
            24,
            |rng, _| {
                let groups = [1usize, 2][rng.range(0, 1)];
                let cin = groups * rng.range(1, 4);
                let cout = groups * rng.range(1, 4);
                let k = [1usize, 3][rng.range(0, 1)];
                let h = rng.range(k.max(2), k + 4);
                (random_conv(rng, groups, k, cin, cout), random_input(rng, h, cin))
            },
            |(conv, x)| {
                let mut ma = CountingMonitor::new();
                let a = conv.forward_simd(x, &mut ma);
                let mut mb = CountingMonitor::new();
                let b = conv_im2col_blocked(conv, x, 2, 2, &mut mb);
                ensure_eq_i8(&a.data, &b.data, "blocked 2x2 result")?;
                if ma.counts != mb.counts {
                    return Err(format!(
                        "event mismatch: simd {:?} vs blocked {:?}",
                        ma.counts, mb.counts
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn blocked_conv_matches_scalar_for_every_feasible_blocking() {
        let mut rng = Rng::new(0x5_0ACE);
        for &(p, f) in &blocking_options() {
            let conv = random_conv(&mut rng, 2, 3, 4, 6);
            let x = random_input(&mut rng, 5, 4);
            let want = conv.forward_scalar(&x, &mut NoopMonitor);
            let got = conv_im2col_blocked(&conv, &x, p, f, &mut NoopMonitor);
            assert_eq!(want.data, got.data, "({p},{f})");
        }
    }

    #[test]
    fn larger_blocking_reduces_memory_accesses() {
        let mut rng = Rng::new(7);
        let conv = random_conv(&mut rng, 1, 3, 8, 8);
        let x = random_input(&mut rng, 8, 8);
        let count = |p: usize, f: usize| {
            let mut mon = CountingMonitor::new();
            conv_im2col_blocked(&conv, &x, p, f, &mut mon);
            mon.counts.mem_accesses()
        };
        assert!(count(2, 2) < count(1, 1));
        // (3,2) fits the register file and reuses strictly more than 2x2
        assert!(count(3, 2) < count(2, 2));
    }

    #[test]
    fn substitutions_are_bit_exact() {
        let mut rng = Rng::new(0xD1CE);
        // depthwise-shaped conv <-> depthwise kernel
        let dwc = random_conv(&mut rng, 4, 3, 4, 4);
        let x = random_input(&mut rng, 6, 4);
        let base = dwc.forward_scalar(&x, &mut NoopMonitor);
        let as_dw = conv_to_depthwise(&dwc);
        assert_eq!(base.data, as_dw.forward_scalar(&x, &mut NoopMonitor).data);
        assert_eq!(base.data, as_dw.forward_simd(&x, &mut NoopMonitor).data);
        // and back: depthwise -> grouped conv
        let back = depthwise_to_conv(&as_dw);
        assert_eq!(base.data, back.forward_scalar(&x, &mut NoopMonitor).data);
        // pointwise conv <-> zero-shift shift conv
        let pw = random_conv(&mut rng, 1, 1, 5, 3);
        let xp = random_input(&mut rng, 4, 5);
        let want = pw.forward_scalar(&xp, &mut NoopMonitor);
        let s = pointwise_to_shift(&pw);
        assert_eq!(want.data, s.forward_scalar(&xp, &mut NoopMonitor).data);
        assert_eq!(want.data, s.forward_simd(&xp, &mut NoopMonitor).data);
    }

    #[test]
    fn every_candidate_of_every_layer_kind_is_bit_exact() {
        let mut rng = Rng::new(0xBEEF);
        let p = crate::models::LayerParams::new(2, 3, 6, 4, 4);
        for prim in crate::analytic::Primitive::ALL {
            let model = crate::models::experiment_layer(&p, prim, 5);
            let x = crate::models::experiment_input(&p, 6);
            let mut t = x.clone();
            for layer in &model.layers {
                let want = layer.forward(&t, false, &mut NoopMonitor);
                for cand in candidates(layer) {
                    let got = execute(layer, &cand, &t, &mut NoopMonitor);
                    assert_eq!(
                        want.data, got.data,
                        "{prim:?}/{}/{cand:?}",
                        layer.name()
                    );
                }
                t = want;
            }
        }
        // dense too (not part of the single-layer experiments)
        let d = crate::nn::QuantDense {
            in_features: 12,
            out_features: 5,
            weights: {
                let mut w = vec![0i8; 60];
                rng.fill_i8(&mut w, -10, 10);
                w
            },
            bias: vec![3; 5],
            q_in: QParam::new(7),
            q_w: QParam::new(7),
            q_out: QParam::new(5),
        };
        let layer = Layer::Dense(d);
        let mut x = Tensor::zeros(Shape::new(1, 1, 12), QParam::new(7));
        rng.fill_i8(&mut x.data, -16, 16);
        let want = layer.forward(&x, false, &mut NoopMonitor);
        for cand in candidates(&layer) {
            let got = execute(&layer, &cand, &x, &mut NoopMonitor);
            assert_eq!(want.data, got.data, "dense/{cand:?}");
        }
    }

    #[test]
    fn applies_is_equivalent_to_space_membership() {
        // the O(1) validator must agree with the enumerated space, both
        // on every legal candidate and on representative illegal ones
        let p = crate::models::LayerParams::new(2, 3, 6, 4, 4);
        let mut rng = Rng::new(0xAB1);
        let mut layers: Vec<Layer> = Vec::new();
        for prim in crate::analytic::Primitive::ALL {
            layers.extend(crate::models::experiment_layer(&p, prim, 21).layers);
        }
        layers.push(Layer::Conv(random_conv(&mut rng, 4, 3, 4, 4))); // depthwise-shaped
        layers.push(Layer::Conv(random_conv(&mut rng, 1, 1, 5, 3))); // pointwise
        let mut probes: Vec<Candidate> = Vec::new();
        for backend in [Backend::ScalarRef, Backend::VecLanes] {
            for kernel in [
                KernelImpl::AsIs,
                KernelImpl::ConvAsDepthwise,
                KernelImpl::DepthwiseAsConv,
                KernelImpl::PointwiseAsShift,
            ] {
                probes.push(Candidate { kernel, lowering: Lowering::Direct, backend });
                for patches in 1..=5usize {
                    for filters in 1..=5usize {
                        probes.push(Candidate {
                            kernel,
                            lowering: Lowering::Im2col { patches, filters },
                            backend,
                        });
                    }
                }
            }
        }
        for layer in &layers {
            let space = candidates(layer);
            for cand in &probes {
                assert_eq!(
                    applies(layer, cand),
                    space.contains(cand),
                    "{}/{cand:?}",
                    layer.name()
                );
            }
            // and every enumerated candidate validates
            for cand in &space {
                assert!(applies(layer, cand), "{}/{cand:?}", layer.name());
            }
        }
    }

    #[test]
    fn analytic_counts_equal_instrumented_counts_across_the_space() {
        // The load-bearing equality behind analytic scoring: for every
        // candidate of every layer kind, the closed-form counts are the
        // counted event stream, bit for bit.
        let p = crate::models::LayerParams::new(2, 3, 6, 4, 4);
        for prim in crate::analytic::Primitive::ALL {
            let model = crate::models::experiment_layer(&p, prim, 9);
            let x = crate::models::experiment_input(&p, 10);
            let mut t = x.clone();
            for layer in &model.layers {
                for cand in candidates(layer) {
                    let mut mon = CountingMonitor::new();
                    execute(layer, &cand, &t, &mut mon);
                    let got = analytic_counts(layer, &cand, &t.shape);
                    assert_eq!(got, mon.counts, "{prim:?}/{}/{cand:?}", layer.name());
                }
                t = layer.forward(&t, false, &mut NoopMonitor);
            }
        }
    }

    #[test]
    fn analytic_counts_equal_instrumented_counts_randomized() {
        // randomized kernel / pad / groups / channels / H×W / blocking,
        // including non-square inputs and pad-0 layers
        check(
            "space-analytic-vs-counted",
            32,
            |rng, i| {
                let groups = [1usize, 2, 4][rng.range(0, 2)];
                let cin = groups * rng.range(1, 4);
                let cout = groups * rng.range(1, 4);
                let k = [1usize, 3, 5][rng.range(0, 2)];
                let h = rng.range(k, k + 4);
                let w = rng.range(k, k + 4);
                let mut conv = random_conv(rng, groups, k, cin, cout);
                if i % 3 == 0 {
                    conv.pad = 0;
                }
                let mut x = Tensor::zeros(Shape::new(h, w, cin), QParam::new(7));
                rng.fill_i8(&mut x.data, -16, 16);
                (Layer::Conv(conv), x)
            },
            |(layer, x)| {
                for cand in candidates(layer) {
                    let mut mon = CountingMonitor::new();
                    execute(layer, &cand, x, &mut mon);
                    let got = analytic_counts(layer, &cand, &x.shape);
                    if got != mon.counts {
                        return Err(format!(
                            "{cand:?}: analytic {got:?} vs counted {:?}",
                            mon.counts
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn signatures_discriminate_shape_and_config() {
        let mut rng = Rng::new(3);
        let a = Layer::Conv(random_conv(&mut rng, 1, 3, 4, 4));
        let b = Layer::Conv(random_conv(&mut rng, 2, 3, 4, 4));
        let s1 = Shape::new(6, 6, 4);
        let s2 = Shape::new(8, 8, 4);
        assert_ne!(layer_signature(&a, &s1), layer_signature(&b, &s1));
        assert_ne!(layer_signature(&a, &s1), layer_signature(&a, &s2));
        // weight values do not enter the signature
        let mut c1 = random_conv(&mut rng, 1, 3, 4, 4);
        let c2 = {
            let mut c = c1.clone();
            rng.fill_i8(&mut c.weights, -5, 5);
            c
        };
        c1.weights.fill(1);
        assert_eq!(
            layer_signature(&Layer::Conv(c1), &s1),
            layer_signature(&Layer::Conv(c2), &s1)
        );
    }

    #[test]
    fn scratch_accounts_im2col_and_shift_intermediate() {
        let mut rng = Rng::new(9);
        let c = random_conv(&mut rng, 1, 3, 8, 8);
        let shape = Shape::new(6, 6, 8);
        let direct = Candidate {
            kernel: KernelImpl::AsIs,
            lowering: Lowering::Direct,
            backend: Backend::ScalarRef,
        };
        let im2 = Candidate {
            kernel: KernelImpl::AsIs,
            lowering: Lowering::Im2col { patches: 2, filters: 2 },
            backend: Backend::ScalarRef,
        };
        let im4 = Candidate {
            kernel: KernelImpl::AsIs,
            lowering: Lowering::Im2col { patches: 4, filters: 1 },
            backend: Backend::ScalarRef,
        };
        let layer = Layer::Conv(c);
        assert_eq!(scratch_bytes(&layer, &direct, &shape), 0);
        assert_eq!(scratch_bytes(&layer, &im2, &shape), 2 * 9 * 8 * 2);
        assert_eq!(scratch_bytes(&layer, &im4, &shape), 4 * 9 * 8 * 2);
        assert!(ram_bytes(&layer, &im4, &shape) > ram_bytes(&layer, &im2, &shape));
        // the vec backend reuses the same im2col columns — no extra
        // scratch on the blocked-matmul path
        assert_eq!(
            scratch_bytes(&layer, &Candidate { backend: Backend::VecLanes, ..im2 }, &shape),
            2 * 9 * 8 * 2
        );
        // a pointwise conv substituted onto the shift kernel pays the
        // shift scalar path's materialized intermediate map
        let pw = Layer::Conv(random_conv(&mut rng, 1, 1, 8, 8));
        let pw_as_shift = Candidate {
            kernel: KernelImpl::PointwiseAsShift,
            lowering: Lowering::Direct,
            backend: Backend::ScalarRef,
        };
        assert_eq!(scratch_bytes(&pw, &pw_as_shift, &shape), shape.len());
        assert_eq!(
            scratch_bytes(
                &pw,
                &Candidate {
                    kernel: KernelImpl::AsIs,
                    lowering: Lowering::Direct,
                    backend: Backend::ScalarRef,
                },
                &shape
            ),
            0
        );
        // vec-backend depthwise (native or conv-substituted) pays the
        // per-channel i32 accumulator strip
        let dwc = Layer::Conv(random_conv(&mut rng, 4, 3, 4, 4));
        let dshape = Shape::new(6, 6, 4);
        let cad = Candidate {
            kernel: KernelImpl::ConvAsDepthwise,
            lowering: Lowering::Im2col { patches: 2, filters: 2 },
            backend: Backend::ScalarRef,
        };
        assert_eq!(scratch_bytes(&dwc, &cad, &dshape), 0);
        assert_eq!(
            scratch_bytes(&dwc, &Candidate { backend: Backend::VecLanes, ..cad }, &dshape),
            4 * 4
        );
    }

    #[test]
    fn node_signatures_fold_wiring_but_share_across_chains() {
        use crate::nn::Graph;
        use crate::quant::QParam;
        let mut rng = Rng::new(0x51D);
        let conv = random_conv(&mut rng, 1, 3, 4, 4);
        // chain: conv → relu → relu(previous value)
        let mut chain = Graph::new("c", Shape::new(6, 6, 4), QParam::new(7));
        let v = chain.layer(chain.input(), Layer::Conv(conv.clone()));
        let v = chain.layer(v, Layer::Relu);
        chain.layer(v, Layer::Relu);
        // fan-out: the last relu consumes the conv output instead (same
        // ops, same shapes, different wiring)
        let mut fanout = Graph::new("f", Shape::new(6, 6, 4), QParam::new(7));
        let v = fanout.layer(fanout.input(), Layer::Conv(conv));
        let _ = fanout.layer(v, Layer::Relu);
        fanout.layer(v, Layer::Relu);
        let cs = chain.value_shapes();
        let fs = fanout.value_shapes();
        // node 0 and 1 are wired identically: signatures shared
        for i in 0..2 {
            assert_eq!(
                node_signature(&chain.nodes[i], i, &cs),
                node_signature(&fanout.nodes[i], i, &fs),
                "node {i}"
            );
        }
        // node 2's producer distance differs: the key must too
        assert_ne!(
            node_signature(&chain.nodes[2], 2, &cs),
            node_signature(&fanout.nodes[2], 2, &fs)
        );
        // linear chains carry the unit-distance suffix (cache sharing
        // with every other chain position is preserved)
        assert!(node_signature(&chain.nodes[2], 2, &cs).ends_with("~in1"));
        // and a residual join folds both operand distances
        let mut res = chain.clone();
        let out = res.output_value();
        res.add(1, out, QParam::new(5));
        let rs = res.value_shapes();
        let sig = node_signature(&res.nodes[3], 3, &rs);
        assert!(sig.starts_with("resadd[q5]@6x6x4"), "{sig}");
        assert!(sig.ends_with("~in3,1"), "{sig}");
    }

    #[test]
    fn graph_signature_keys_on_structure_not_name() {
        use crate::nn::Graph;
        let mut rng = Rng::new(0x51);
        let conv = random_conv(&mut rng, 1, 3, 4, 4);
        let build = |name: &str, skip: bool| {
            let mut g = Graph::new(name, Shape::new(6, 6, 4), QParam::new(7));
            let v0 = g.layer(g.input(), Layer::Conv(conv.clone()));
            let v1 = g.layer(v0, Layer::Relu);
            g.layer(if skip { v0 } else { v1 }, Layer::Relu);
            g
        };
        // names differ, structure identical: one frontier serves both
        assert_eq!(
            graph_signature(&build("a", false)),
            graph_signature(&build("b", false))
        );
        // one rewired edge (same ops, same shapes) re-keys
        assert_ne!(
            graph_signature(&build("a", false)),
            graph_signature(&build("a", true))
        );
    }
}
