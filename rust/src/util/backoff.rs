//! Seeded, jittered exponential backoff — substitute for the `backoff`
//! crate in the offline vendor set.
//!
//! Used by the worker supervisor (respawn delays after a panic) and by
//! the client-side retry helper. The delay sequence is exponential with
//! *full-range-halved* jitter: attempt `k` draws uniformly from
//! `[base·2^k / 2, base·2^k]`, clamped to a configured ceiling. The
//! jitter source is the deterministic [`crate::util::prng::Rng`], so a
//! seeded chaos run replays the exact same respawn schedule.

use std::time::Duration;

use crate::util::prng::Rng;

/// Jittered exponential backoff with a deterministic jitter source.
#[derive(Clone, Debug)]
pub struct Backoff {
    base_us: u64,
    max_us: u64,
    attempt: u32,
    rng: Rng,
}

impl Backoff {
    /// Create a policy starting at `base_us` and clamped to `max_us`.
    ///
    /// `seed` drives the jitter; two instances with the same parameters
    /// and seed produce identical delay sequences.
    pub fn new(base_us: u64, max_us: u64, seed: u64) -> Self {
        Self {
            base_us: base_us.max(1),
            max_us: max_us.max(1),
            attempt: 0,
            rng: Rng::new(seed),
        }
    }

    /// Number of delays handed out since construction or the last
    /// [`Backoff::reset`].
    pub fn attempts(&self) -> u32 {
        self.attempt
    }

    /// Forget the failure streak: the next delay starts from `base_us`
    /// again. Called after a worker incarnation serves a batch cleanly.
    pub fn reset(&mut self) {
        self.attempt = 0;
    }

    /// Next delay in the sequence: uniform in `[d/2, d]` where
    /// `d = min(base · 2^attempt, max)`. Advances the attempt counter.
    pub fn next_delay(&mut self) -> Duration {
        let exp = self.attempt.min(32);
        self.attempt = self.attempt.saturating_add(1);
        let ceiling = self
            .base_us
            .saturating_mul(1u64 << exp)
            .min(self.max_us)
            .max(1);
        let floor = (ceiling / 2).max(1);
        let jittered = floor + self.rng.below(ceiling - floor + 1);
        Duration::from_micros(jittered)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delays_grow_exponentially_until_the_clamp() {
        let mut b = Backoff::new(100, 1_600, 7);
        let mut prev_ceiling = 0u64;
        for k in 0..8u32 {
            let d = b.next_delay().as_micros() as u64;
            let ceiling = (100u64 << k.min(32)).min(1_600);
            let floor = (ceiling / 2).max(1);
            assert!(
                d >= floor && d <= ceiling,
                "attempt {k}: delay {d} outside [{floor}, {ceiling}]"
            );
            // the clamp makes the ceiling monotone non-decreasing
            assert!(ceiling >= prev_ceiling);
            prev_ceiling = ceiling;
        }
        // well past the clamp: still bounded by max_us
        for _ in 0..20 {
            assert!(b.next_delay().as_micros() as u64 <= 1_600);
        }
    }

    #[test]
    fn same_seed_replays_the_same_schedule() {
        let mut a = Backoff::new(50, 10_000, 0xC0FFEE);
        let mut b = Backoff::new(50, 10_000, 0xC0FFEE);
        for _ in 0..16 {
            assert_eq!(a.next_delay(), b.next_delay());
        }
    }

    #[test]
    fn reset_restarts_from_the_base_delay() {
        let mut b = Backoff::new(100, 1 << 20, 3);
        for _ in 0..6 {
            b.next_delay();
        }
        assert_eq!(b.attempts(), 6);
        b.reset();
        assert_eq!(b.attempts(), 0);
        let d = b.next_delay().as_micros() as u64;
        assert!((50..=100).contains(&d), "post-reset delay {d} not in [50, 100]");
    }

    #[test]
    fn degenerate_parameters_stay_positive() {
        let mut b = Backoff::new(0, 0, 1);
        for _ in 0..4 {
            assert!(b.next_delay() >= Duration::from_micros(1));
        }
    }
}
