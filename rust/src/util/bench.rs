//! Criterion-style micro-benchmark runner (criterion is not in the offline
//! vendor set). Benches declare `harness = false` and call [`Bench::run`].
//!
//! The runner warms up, then collects wall-clock samples and prints a
//! summary line per benchmark, plus an optional CSV dump for EXPERIMENTS.md.

use std::hint::black_box;
use std::time::{Duration, Instant};

use super::stats::{summarize, Summary};

/// Configuration for a bench run.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    /// Warm-up time before sampling.
    pub warmup: Duration,
    /// Number of measured samples.
    pub samples: usize,
    /// Minimum time per sample (iterations are batched to reach it).
    pub min_sample_time: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            samples: 20,
            min_sample_time: Duration::from_millis(10),
        }
    }
}

/// Quick config for smoke-testing bench binaries (CI / `cargo test`).
impl BenchConfig {
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(10),
            samples: 5,
            min_sample_time: Duration::from_millis(1),
        }
    }
}

/// One benchmark result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall time summary, in nanoseconds.
    pub ns: Summary,
    /// Iterations per sample batch.
    pub iters_per_sample: u64,
}

impl BenchResult {
    pub fn mean_ns(&self) -> f64 {
        self.ns.mean
    }
}

/// The bench runner. Honours `CONVBENCH_QUICK=1` for fast smoke runs.
pub struct Bench {
    config: BenchConfig,
    pub results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Self {
        let config = if std::env::var("CONVBENCH_QUICK").as_deref() == Ok("1") {
            BenchConfig::quick()
        } else {
            BenchConfig::default()
        };
        Self {
            config,
            results: Vec::new(),
        }
    }

    pub fn with_config(config: BenchConfig) -> Self {
        Self {
            config,
            results: Vec::new(),
        }
    }

    /// Time `f` and record + print the result. The closure's return value
    /// is passed through `black_box` to keep the optimizer honest.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        // Warm-up, and estimate iterations per sample batch.
        let warm_start = Instant::now();
        let mut warm_iters: u64 = 0;
        while warm_start.elapsed() < self.config.warmup {
            black_box(f());
            warm_iters += 1;
        }
        let per_iter = self.config.warmup.as_secs_f64() / warm_iters.max(1) as f64;
        let iters = ((self.config.min_sample_time.as_secs_f64() / per_iter).ceil() as u64).max(1);

        let mut samples_ns = Vec::with_capacity(self.config.samples);
        for _ in 0..self.config.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                black_box(f());
            }
            let dt = t0.elapsed();
            samples_ns.push(dt.as_nanos() as f64 / iters as f64);
        }
        let ns = summarize(&samples_ns).expect("non-empty samples");
        let result = BenchResult {
            name: name.to_string(),
            ns,
            iters_per_sample: iters,
        };
        println!(
            "bench {:<52} {:>12.1} ns/iter (±{:>10.1}, median {:>12.1}, n={})",
            result.name, ns.mean, ns.std, ns.median, ns.n
        );
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Dump all results as CSV (name,mean_ns,std_ns,median_ns,min_ns,max_ns).
    pub fn to_csv(&self) -> String {
        let mut s = String::from("name,mean_ns,std_ns,median_ns,min_ns,max_ns\n");
        for r in &self.results {
            s.push_str(&format!(
                "{},{:.1},{:.1},{:.1},{:.1},{:.1}\n",
                r.name, r.ns.mean, r.ns.std, r.ns.median, r.ns.min, r.ns.max
            ));
        }
        s
    }

    /// Write the CSV next to the repo's bench outputs.
    pub fn write_csv(&self, path: &str) {
        if let Some(parent) = std::path::Path::new(path).parent() {
            let _ = std::fs::create_dir_all(parent);
        }
        if let Err(e) = std::fs::write(path, self.to_csv()) {
            eprintln!("warning: could not write {path}: {e}");
        } else {
            println!("wrote {path}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_results() {
        let mut b = Bench::with_config(BenchConfig {
            warmup: Duration::from_millis(1),
            samples: 3,
            min_sample_time: Duration::from_micros(100),
        });
        b.run("noop", || 1 + 1);
        assert_eq!(b.results.len(), 1);
        assert!(b.results[0].ns.mean > 0.0);
        let csv = b.to_csv();
        assert!(csv.starts_with("name,"));
        assert!(csv.contains("noop"));
    }
}
