//! Minimal command-line argument parser (clap is not in the offline vendor
//! set). Supports subcommands, `--flag`, `--key value` / `--key=value` and
//! positional arguments — enough for the `convbench` binary and examples.

use std::collections::BTreeMap;

/// Parsed arguments: subcommand, options, flags and positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Parse from an iterator of argument strings.
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Self {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Get an option value.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Get an option parsed as `T`, or `default` when absent.
    /// Panics with a readable message on a malformed value.
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T
    where
        T::Err: std::fmt::Display,
    {
        match self.options.get(key) {
            None => default,
            Some(v) => match v.parse() {
                Ok(x) => x,
                Err(e) => panic!("invalid value for --{key}: {v:?} ({e})"),
            },
        }
    }

    /// Whether a bare `--flag` was passed.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("fig2 --exp 3 --out results.csv");
        assert_eq!(a.subcommand.as_deref(), Some("fig2"));
        assert_eq!(a.get("exp"), Some("3"));
        assert_eq!(a.get("out"), Some("results.csv"));
    }

    #[test]
    fn equals_form_and_flags() {
        let a = parse("serve --port=8080 --verbose");
        assert_eq!(a.get("port"), Some("8080"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_get_or() {
        let a = parse("x --n 12");
        assert_eq!(a.get_or("n", 5usize), 12);
        assert_eq!(a.get_or("m", 5usize), 5);
    }

    #[test]
    fn positionals() {
        let a = parse("run model.hlo.txt input.bin");
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.positional, vec!["model.hlo.txt", "input.bin"]);
    }

    #[test]
    #[should_panic(expected = "invalid value")]
    fn malformed_typed_value_panics() {
        let a = parse("x --n twelve");
        let _: usize = a.get_or("n", 0);
    }
}
