//! Deterministic fault injection for the serving worker loop.
//!
//! The chaos harness (`convbench chaos`) needs to provoke worker panics,
//! stalls and error returns *reproducibly*, without taxing the
//! production path. The design mirrors the zero-cost `TraceSink`
//! pattern from `obs::trace`: a [`FaultInjector`] trait whose no-op
//! implementation ([`NoopFaults`]) inlines away entirely, and a seeded
//! implementation ([`SeededFaults`]) that rolls a deterministic die at
//! each named [`FaultSite`] in the worker loop. The worker loop is
//! generic over the injector, so a server started without a
//! [`FaultPlan`] monomorphises to exactly the code it had before this
//! module existed.

use std::time::Duration;

use crate::util::cli::Args;
use crate::util::prng::Rng;

/// Named injection points inside the worker batch-serving path.
///
/// Each drained batch passes the sites in order; the catalog is part of
/// the documented fault model (see `docs/ARCHITECTURE.md`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSite {
    /// Before inputs are staged into the batch arena.
    Stage,
    /// Before the compiled plan executes the staged batch.
    Exec,
    /// Before per-lane replies are sent.
    Respond,
}

/// What the injector decided for one pass through a site.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// Proceed normally.
    None,
    /// Panic at the site (caught by the worker supervisor).
    Panic,
    /// Sleep for the given duration, then proceed.
    Delay(Duration),
    /// Fail the batch with a typed retriable error instead of panicking.
    Error,
}

/// Injection rates and seed for a chaos run. Rates are per-million per
/// site visit; the all-zero default ([`FaultPlan::disabled`]) injects
/// nothing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultPlan {
    /// Seed for the per-worker dice (worker id is folded in).
    pub seed: u64,
    /// Probability of a panic per site visit, in parts per million.
    pub panic_ppm: u32,
    /// Probability of a delay per site visit, in parts per million.
    pub delay_ppm: u32,
    /// Probability of an error return per site visit, in parts per million.
    pub error_ppm: u32,
    /// Duration of an injected delay, in microseconds.
    pub delay_us: u64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        Self::disabled()
    }
}

impl FaultPlan {
    /// The inert plan: all rates zero.
    pub fn disabled() -> Self {
        Self {
            seed: 0,
            panic_ppm: 0,
            delay_ppm: 0,
            error_ppm: 0,
            delay_us: 0,
        }
    }

    /// True when any injection rate is nonzero — the server only pays
    /// for fault dice when this holds.
    pub fn enabled(&self) -> bool {
        self.panic_ppm > 0 || self.delay_ppm > 0 || self.error_ppm > 0
    }

    /// Parse `--fault-seed`, `--panic-ppm`, `--delay-ppm`,
    /// `--error-ppm` and `--fault-delay-us` from CLI arguments.
    pub fn from_args(args: &Args) -> Self {
        Self {
            seed: args.get_or("fault-seed", 0u64),
            panic_ppm: args.get_or("panic-ppm", 0u32),
            delay_ppm: args.get_or("delay-ppm", 0u32),
            error_ppm: args.get_or("error-ppm", 0u32),
            delay_us: args.get_or("fault-delay-us", 200u64),
        }
    }
}

/// Zero-cost fault hook for the worker loop.
///
/// The default method body is the production behaviour; `NoopFaults`
/// adds nothing on top, so the non-chaos monomorphisation of the worker
/// loop contains no branches for injection.
pub trait FaultInjector: Send + 'static {
    /// Roll the dice at `site`; the worker acts on the returned action.
    #[inline]
    fn roll(&mut self, _site: FaultSite) -> FaultAction {
        FaultAction::None
    }
}

/// The production injector: never injects, compiles away.
#[derive(Clone, Copy, Debug, Default)]
pub struct NoopFaults;

impl FaultInjector for NoopFaults {}

/// Seeded injector: one deterministic die per worker, partitioned into
/// panic / delay / error bands so a single draw decides the action.
#[derive(Clone, Debug)]
pub struct SeededFaults {
    plan: FaultPlan,
    rng: Rng,
}

impl SeededFaults {
    /// Build the injector for one worker; `worker_id` is folded into the
    /// plan seed so workers roll independent but reproducible dice.
    pub fn new(plan: FaultPlan, worker_id: u64) -> Self {
        let rng = Rng::new(plan.seed ^ worker_id.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        Self { plan, rng }
    }
}

impl FaultInjector for SeededFaults {
    fn roll(&mut self, _site: FaultSite) -> FaultAction {
        let draw = self.rng.below(1_000_000) as u32;
        let panic_hi = self.plan.panic_ppm;
        let delay_hi = panic_hi.saturating_add(self.plan.delay_ppm);
        let error_hi = delay_hi.saturating_add(self.plan.error_ppm);
        if draw < panic_hi {
            FaultAction::Panic
        } else if draw < delay_hi {
            FaultAction::Delay(Duration::from_micros(self.plan.delay_us))
        } else if draw < error_hi {
            FaultAction::Error
        } else {
            FaultAction::None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_plan_reports_disabled_and_noop_never_injects() {
        assert!(!FaultPlan::disabled().enabled());
        let mut noop = NoopFaults;
        for site in [FaultSite::Stage, FaultSite::Exec, FaultSite::Respond] {
            assert_eq!(noop.roll(site), FaultAction::None);
        }
    }

    #[test]
    fn seeded_faults_replay_identically() {
        let plan = FaultPlan {
            seed: 42,
            panic_ppm: 300_000,
            delay_ppm: 200_000,
            error_ppm: 100_000,
            delay_us: 50,
        };
        assert!(plan.enabled());
        let mut a = SeededFaults::new(plan, 1);
        let mut b = SeededFaults::new(plan, 1);
        for _ in 0..256 {
            assert_eq!(a.roll(FaultSite::Exec), b.roll(FaultSite::Exec));
        }
    }

    #[test]
    fn distinct_workers_roll_distinct_dice() {
        let plan = FaultPlan {
            seed: 42,
            panic_ppm: 500_000,
            delay_ppm: 0,
            error_ppm: 0,
            delay_us: 0,
        };
        let mut a = SeededFaults::new(plan, 0);
        let mut b = SeededFaults::new(plan, 1);
        let same = (0..64)
            .filter(|_| a.roll(FaultSite::Stage) == b.roll(FaultSite::Stage))
            .count();
        assert!(same < 64, "two workers rolled 64 identical actions");
    }

    #[test]
    fn rates_partition_the_draw_space() {
        // with panic+delay+error == 1_000_000 every roll injects something
        let plan = FaultPlan {
            seed: 9,
            panic_ppm: 400_000,
            delay_ppm: 300_000,
            error_ppm: 300_000,
            delay_us: 10,
        };
        let mut f = SeededFaults::new(plan, 3);
        let (mut p, mut d, mut e) = (0u32, 0u32, 0u32);
        for _ in 0..1_000 {
            match f.roll(FaultSite::Respond) {
                FaultAction::Panic => p += 1,
                FaultAction::Delay(dur) => {
                    assert_eq!(dur, Duration::from_micros(10));
                    d += 1;
                }
                FaultAction::Error => e += 1,
                FaultAction::None => panic!("saturated plan rolled None"),
            }
        }
        assert!(p > 0 && d > 0 && e > 0);
    }
}
