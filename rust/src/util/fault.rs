//! Deterministic fault injection for the serving worker loop.
//!
//! The chaos harness (`convbench chaos`) needs to provoke worker panics,
//! stalls and error returns *reproducibly*, without taxing the
//! production path. The design mirrors the zero-cost `TraceSink`
//! pattern from `obs::trace`: a [`FaultInjector`] trait whose no-op
//! implementation ([`NoopFaults`]) inlines away entirely, and a seeded
//! implementation ([`SeededFaults`]) that rolls a deterministic die at
//! each named [`FaultSite`] in the worker loop. The worker loop is
//! generic over the injector, so a server started without a
//! [`FaultPlan`] monomorphises to exactly the code it had before this
//! module existed.
//!
//! # Key-rolled determinism
//!
//! The dice are **stateless**: every roll is a pure function of
//! `(plan seed, site, key)`, where the key identifies the *work* being
//! rolled for — [`batch_key`] folds the batch lanes' (request id,
//! attempt) pairs. Nothing about worker identity, visit order or
//! wall-clock timing enters the draw, so the same request content
//! suffers the same fault in every run and under every thread
//! interleaving. (A sequential per-worker die would make outcomes depend
//! on which worker won the queue race — the nondeterminism this design
//! replaced.) The attempt number is part of the key on purpose: retries
//! resubmit under the *same request id*, and keying by id alone would
//! doom a panic-marked request to panic on every attempt, turning every
//! injected panic into a permanent failure instead of a retry exercise.

use std::time::Duration;

use crate::util::cli::Args;
use crate::util::fnv::Fnv1a;
use crate::util::prng::Rng;

/// Named injection points inside the worker batch-serving path.
///
/// Each drained batch passes the sites in order; the catalog is part of
/// the documented fault model (see `docs/ARCHITECTURE.md`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSite {
    /// Before inputs are staged into the batch arena.
    Stage,
    /// Before the compiled plan executes the staged batch.
    Exec,
    /// Before per-lane replies are sent.
    Respond,
}

impl FaultSite {
    /// Per-site salt folded into the die seed, so one batch rolls
    /// independent dice at its three sites.
    fn salt(self) -> u64 {
        match self {
            FaultSite::Stage => 0x5354_4147_45,
            FaultSite::Exec => 0x4558_4543,
            FaultSite::Respond => 0x5245_5350_4f,
        }
    }
}

/// What the injector decided for one pass through a site.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// Proceed normally.
    None,
    /// Panic at the site (caught by the worker supervisor).
    Panic,
    /// Sleep for the given duration, then proceed.
    Delay(Duration),
    /// Fail the batch with a typed retriable error instead of panicking.
    Error,
}

/// Injection rates and seed for a chaos run. Rates are per-million per
/// site visit; the all-zero default ([`FaultPlan::disabled`]) injects
/// nothing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultPlan {
    /// Seed for the fault dice (folded with the per-roll key and site).
    pub seed: u64,
    /// Probability of a panic per site visit, in parts per million.
    pub panic_ppm: u32,
    /// Probability of a delay per site visit, in parts per million.
    pub delay_ppm: u32,
    /// Probability of an error return per site visit, in parts per million.
    pub error_ppm: u32,
    /// Duration of an injected delay, in microseconds.
    pub delay_us: u64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        Self::disabled()
    }
}

impl FaultPlan {
    /// The inert plan: all rates zero.
    pub fn disabled() -> Self {
        Self {
            seed: 0,
            panic_ppm: 0,
            delay_ppm: 0,
            error_ppm: 0,
            delay_us: 0,
        }
    }

    /// True when any injection rate is nonzero — the server only pays
    /// for fault dice when this holds.
    pub fn enabled(&self) -> bool {
        self.panic_ppm > 0 || self.delay_ppm > 0 || self.error_ppm > 0
    }

    /// Parse `--fault-seed`, `--panic-ppm`, `--delay-ppm`,
    /// `--error-ppm` and `--fault-delay-us` from CLI arguments.
    pub fn from_args(args: &Args) -> Self {
        Self {
            seed: args.get_or("fault-seed", 0u64),
            panic_ppm: args.get_or("panic-ppm", 0u32),
            delay_ppm: args.get_or("delay-ppm", 0u32),
            error_ppm: args.get_or("error-ppm", 0u32),
            delay_us: args.get_or("fault-delay-us", 200u64),
        }
    }
}

/// The deterministic fault key of one batch: FNV-1a over the lanes'
/// (request id, attempt) pairs, in lane order. Worker identity and
/// timing are deliberately absent — the same batch content rolls the
/// same dice in any interleaving.
pub fn batch_key(lanes: impl Iterator<Item = (u64, u32)>) -> u64 {
    let mut h = Fnv1a::new();
    for (id, attempt) in lanes {
        for b in id.to_le_bytes() {
            h.byte(b);
        }
        for b in attempt.to_le_bytes() {
            h.byte(b);
        }
    }
    h.finish()
}

/// Zero-cost fault hook for the worker loop.
///
/// The default method body is the production behaviour; `NoopFaults`
/// adds nothing on top, so the non-chaos monomorphisation of the worker
/// loop contains no branches for injection.
pub trait FaultInjector: Send + 'static {
    /// Roll the dice at `site` for the work identified by `key` (see
    /// [`batch_key`]); the worker acts on the returned action.
    #[inline]
    fn roll(&mut self, _site: FaultSite, _key: u64) -> FaultAction {
        FaultAction::None
    }
}

/// The production injector: never injects, compiles away.
#[derive(Clone, Copy, Debug, Default)]
pub struct NoopFaults;

impl FaultInjector for NoopFaults {}

/// Seeded injector: each roll seeds a fresh die from
/// `(plan seed, key, site)` and partitions one draw into panic / delay /
/// error bands. Stateless, so outcomes are independent of worker
/// identity and visit order — identical storms produce identical fault
/// schedules.
#[derive(Clone, Copy, Debug)]
pub struct SeededFaults {
    plan: FaultPlan,
}

impl SeededFaults {
    /// Build the injector for a worker. All workers share the same
    /// stateless dice — which worker serves a batch must not change
    /// what happens to it.
    pub fn new(plan: FaultPlan) -> Self {
        Self { plan }
    }
}

impl FaultInjector for SeededFaults {
    fn roll(&mut self, site: FaultSite, key: u64) -> FaultAction {
        let seed = self
            .plan
            .seed
            .wrapping_add(key.wrapping_mul(0x9E37_79B9_7F4A_7C15))
            ^ site.salt();
        let draw = Rng::new(seed).below(1_000_000) as u32;
        let panic_hi = self.plan.panic_ppm;
        let delay_hi = panic_hi.saturating_add(self.plan.delay_ppm);
        let error_hi = delay_hi.saturating_add(self.plan.error_ppm);
        if draw < panic_hi {
            FaultAction::Panic
        } else if draw < delay_hi {
            FaultAction::Delay(Duration::from_micros(self.plan.delay_us))
        } else if draw < error_hi {
            FaultAction::Error
        } else {
            FaultAction::None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_plan_reports_disabled_and_noop_never_injects() {
        assert!(!FaultPlan::disabled().enabled());
        let mut noop = NoopFaults;
        for site in [FaultSite::Stage, FaultSite::Exec, FaultSite::Respond] {
            assert_eq!(noop.roll(site, 123), FaultAction::None);
        }
    }

    #[test]
    fn rolls_are_pure_functions_of_seed_site_and_key() {
        let plan = FaultPlan {
            seed: 42,
            panic_ppm: 300_000,
            delay_ppm: 200_000,
            error_ppm: 100_000,
            delay_us: 50,
        };
        assert!(plan.enabled());
        let mut a = SeededFaults::new(plan);
        let mut b = SeededFaults::new(plan);
        // same (site, key) → same action, regardless of what else each
        // injector rolled before (statelessness is the whole point)
        for warmup in 0..7 {
            a.roll(FaultSite::Stage, warmup);
        }
        for key in 0..256u64 {
            assert_eq!(
                a.roll(FaultSite::Exec, key),
                b.roll(FaultSite::Exec, key),
                "key {key}"
            );
        }
    }

    #[test]
    fn keys_and_sites_decorrelate_the_dice() {
        let plan = FaultPlan {
            seed: 42,
            panic_ppm: 500_000,
            delay_ppm: 0,
            error_ppm: 0,
            delay_us: 0,
        };
        let mut f = SeededFaults::new(plan);
        // distinct keys must not all roll the same action…
        let same_key = (0..64u64)
            .filter(|&k| f.roll(FaultSite::Stage, k) == f.roll(FaultSite::Stage, 0))
            .count();
        assert!(same_key < 64, "64 distinct keys rolled identical actions");
        // …and one key must roll independent dice at the three sites
        let per_site: Vec<FaultAction> = [FaultSite::Stage, FaultSite::Exec, FaultSite::Respond]
            .iter()
            .map(|&s| f.roll(s, 0xFEED))
            .collect();
        let all_equal = per_site.windows(2).all(|w| w[0] == w[1]);
        // not a hard guarantee for one key, so probe a few
        let varied = (0..16u64).any(|k| {
            let acts: Vec<FaultAction> = [FaultSite::Stage, FaultSite::Exec, FaultSite::Respond]
                .iter()
                .map(|&s| f.roll(s, k))
                .collect();
            acts.windows(2).any(|w| w[0] != w[1])
        });
        assert!(varied || !all_equal, "sites never decorrelated over 16 keys");
    }

    #[test]
    fn attempt_number_rerolls_a_retried_request() {
        // a panic-marked (id, attempt) must not doom every retry of the
        // same id: folding the attempt into the key gives each attempt
        // fresh dice
        let plan = FaultPlan {
            seed: 7,
            panic_ppm: 400_000,
            delay_ppm: 0,
            error_ppm: 0,
            delay_us: 0,
        };
        let mut f = SeededFaults::new(plan);
        let doomed = (0..64u64).all(|id| {
            let k0 = batch_key([(id, 0u32)].into_iter());
            let k1 = batch_key([(id, 1u32)].into_iter());
            f.roll(FaultSite::Exec, k0) == FaultAction::Panic
                && f.roll(FaultSite::Exec, k1) == FaultAction::Panic
        });
        assert!(!doomed, "retries rolled the same dice as the first attempt");
    }

    #[test]
    fn batch_key_is_order_and_content_sensitive() {
        let a = batch_key([(1u64, 0u32), (2, 0)].into_iter());
        let b = batch_key([(2u64, 0u32), (1, 0)].into_iter());
        let c = batch_key([(1u64, 1u32), (2, 0)].into_iter());
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_eq!(a, batch_key([(1u64, 0u32), (2, 0)].into_iter()));
    }

    #[test]
    fn rates_partition_the_draw_space() {
        // with panic+delay+error == 1_000_000 every roll injects something
        let plan = FaultPlan {
            seed: 9,
            panic_ppm: 400_000,
            delay_ppm: 300_000,
            error_ppm: 300_000,
            delay_us: 10,
        };
        let mut f = SeededFaults::new(plan);
        let (mut p, mut d, mut e) = (0u32, 0u32, 0u32);
        for key in 0..1_000u64 {
            match f.roll(FaultSite::Respond, key) {
                FaultAction::Panic => p += 1,
                FaultAction::Delay(dur) => {
                    assert_eq!(dur, Duration::from_micros(10));
                    d += 1;
                }
                FaultAction::Error => e += 1,
                FaultAction::None => panic!("saturated plan rolled None"),
            }
        }
        assert!(p > 0 && d > 0 && e > 0);
    }
}
