//! Shared 64-bit FNV-1a hashing — used wherever the repo needs a cheap,
//! dependency-free, deterministic fingerprint (tuner layer signatures,
//! workspace parameter fingerprints). One implementation, one pair of
//! constants.

/// FNV-1a 64-bit offset basis.
pub const FNV_OFFSET: u64 = 0xcbf29ce484222325;
/// FNV-1a 64-bit prime.
pub const FNV_PRIME: u64 = 0x100000001b3;

/// Incremental FNV-1a hasher.
#[derive(Clone, Copy, Debug)]
pub struct Fnv1a(u64);

impl Default for Fnv1a {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv1a {
    pub fn new() -> Self {
        Self(FNV_OFFSET)
    }

    #[inline]
    pub fn byte(&mut self, b: u8) {
        self.0 = (self.0 ^ b as u64).wrapping_mul(FNV_PRIME);
    }

    pub fn i8s(&mut self, xs: &[i8]) {
        for &x in xs {
            self.byte(x as u8);
        }
    }

    pub fn i16s(&mut self, xs: &[i16]) {
        for &x in xs {
            self.0 = (self.0 ^ (x as u16 as u64)).wrapping_mul(FNV_PRIME);
        }
    }

    pub fn i32s(&mut self, xs: &[i32]) {
        for &x in xs {
            self.0 = (self.0 ^ (x as u32 as u64)).wrapping_mul(FNV_PRIME);
        }
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_order_sensitive() {
        let mut a = Fnv1a::new();
        a.i8s(&[1, 2, 3]);
        let mut b = Fnv1a::new();
        b.i8s(&[1, 2, 3]);
        assert_eq!(a.finish(), b.finish());
        let mut c = Fnv1a::new();
        c.i8s(&[3, 2, 1]);
        assert_ne!(a.finish(), c.finish());
    }

    #[test]
    fn width_matters() {
        // the same numeric values hashed at different widths differ
        let mut a = Fnv1a::new();
        a.i8s(&[5]);
        let mut b = Fnv1a::new();
        b.i16s(&[5]);
        assert_ne!(a.finish(), b.finish());
        let mut d = Fnv1a::new();
        d.i32s(&[-1]);
        let mut e = Fnv1a::new();
        e.i16s(&[-1]);
        assert_ne!(d.finish(), e.finish());
    }

    #[test]
    fn single_byte_reference_value() {
        // FNV-1a('a') — the published test vector
        let mut h = Fnv1a::new();
        h.byte(b'a');
        assert_eq!(h.finish(), 0xaf63dc4c8601ec8c);
    }
}
