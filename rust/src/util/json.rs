//! Tiny JSON writer (serde is not in the offline vendor set). Only what the
//! report/metrics paths need: objects, arrays, strings, numbers, bools.
//! Emission only — configs are plain Rust structs, so no parser is needed.

use std::fmt::Write as _;

/// A JSON value builder.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Self {
        Json::Obj(Vec::new())
    }

    /// Insert a field (builder style; panics if not an object).
    pub fn field(mut self, key: &str, value: impl Into<Json>) -> Self {
        match &mut self {
            Json::Obj(fields) => fields.push((key.to_string(), value.into())),
            _ => panic!("Json::field on non-object"),
        }
        self
    }

    /// Serialize to a compact string.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Num(x) => {
                if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Self {
        Json::Int(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Self {
        Json::Int(x as i64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Int(x as i64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn object_roundtrip_text() {
        let j = Json::obj()
            .field("name", "fig2")
            .field("points", 12usize)
            .field("r2", 0.995)
            .field("ok", true);
        assert_eq!(
            j.to_string(),
            r#"{"name":"fig2","points":12,"r2":0.995,"ok":true}"#
        );
    }

    #[test]
    fn array_and_nesting() {
        let j = Json::Arr(vec![Json::Int(1), Json::obj().field("x", 2i64)]);
        assert_eq!(j.to_string(), r#"[1,{"x":2}]"#);
    }

    #[test]
    fn string_escaping() {
        let j = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(j.to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn non_finite_is_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }
}
