//! Tiny JSON reader/writer (serde is not in the offline vendor set). Only
//! what the report/metrics/tuning-cache paths need: objects, arrays,
//! strings, numbers, bools — emission plus a small recursive-descent
//! parser (the [`crate::tuner`] cache persists schedules across runs).

use std::fmt::Write as _;

/// A JSON value builder.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Self {
        Json::Obj(Vec::new())
    }

    /// Insert a field (builder style; panics if not an object).
    pub fn field(mut self, key: &str, value: impl Into<Json>) -> Self {
        match &mut self {
            Json::Obj(fields) => fields.push((key.to_string(), value.into())),
            _ => panic!("Json::field on non-object"),
        }
        self
    }

    /// Serialize to a compact string.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Parse a JSON document. Accepts the subset this module emits (which
    /// is all of JSON except unicode escapes beyond `\uXXXX` in the BMP).
    pub fn parse(s: &str) -> Result<Json, String> {
        let bytes = s.as_bytes();
        let mut p = Parser { bytes, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }

    /// Object field lookup (first match; `None` on non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            Json::Num(x) if x.fract() == 0.0 && x.abs() < 9e15 => Some(*x as i64),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            Json::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(fields) => Some(fields),
            _ => None,
        }
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Num(x) => {
                if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected {:?} at byte {}, found {:?}",
                c as char,
                self.pos,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            None => Err("unexpected end of input".into()),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(format!("unexpected {:?} at byte {}", c as char, self.pos)),
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            fields.push((key, value));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err("truncated \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| "bad \\u escape".to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.pos += 1;
                }
                Some(b) => {
                    // consume one UTF-8 character; the input arrived as
                    // &str, so `pos` sits on a char boundary and the
                    // leading byte gives the width
                    let width = match b {
                        0x00..=0x7F => 1,
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (self.pos + width).min(self.bytes.len());
                    let s = std::str::from_utf8(&self.bytes[self.pos..end])
                        .map_err(|_| "invalid utf-8".to_string())?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let mut float = false;
        while let Some(c) = self.peek() {
            match c {
                b'0'..=b'9' => self.pos += 1,
                b'.' | b'e' | b'E' | b'+' | b'-' => {
                    float = true;
                    self.pos += 1;
                }
                _ => break,
            }
        }
        let tok = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| "invalid number".to_string())?;
        if !float {
            if let Ok(i) = tok.parse::<i64>() {
                return Ok(Json::Int(i));
            }
        }
        tok.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("invalid number {tok:?}: {e}"))
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Self {
        Json::Int(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Self {
        Json::Int(x as i64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Int(x as i64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn object_roundtrip_text() {
        let j = Json::obj()
            .field("name", "fig2")
            .field("points", 12usize)
            .field("r2", 0.995)
            .field("ok", true);
        assert_eq!(
            j.to_string(),
            r#"{"name":"fig2","points":12,"r2":0.995,"ok":true}"#
        );
    }

    #[test]
    fn array_and_nesting() {
        let j = Json::Arr(vec![Json::Int(1), Json::obj().field("x", 2i64)]);
        assert_eq!(j.to_string(), r#"[1,{"x":2}]"#);
    }

    #[test]
    fn string_escaping() {
        let j = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(j.to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn non_finite_is_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn parse_roundtrips_emitted_document() {
        let j = Json::obj()
            .field("name", "tuned schedule \"v1\"\n")
            .field("layers", vec![1i64, 2, 3])
            .field("latency_s", 0.0125)
            .field("count", -42i64)
            .field("warm", true)
            .field("note", Json::Null);
        let text = j.to_string();
        let back = Json::parse(&text).expect("parse");
        assert_eq!(back, j);
        // and re-emission is stable
        assert_eq!(back.to_string(), text);
    }

    #[test]
    fn parse_whitespace_and_nesting() {
        let j = Json::parse(
            "  { \"a\" : [ 1 , 2.5 , { \"b\" : [ ] } ] ,\n\t\"c\" : \"x\" } ",
        )
        .unwrap();
        assert_eq!(j.get("c").and_then(|v| v.as_str()), Some("x"));
        let arr = j.get("a").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(arr[0].as_i64(), Some(1));
        assert_eq!(arr[1].as_f64(), Some(2.5));
        assert!(arr[2].get("b").and_then(|v| v.as_arr()).unwrap().is_empty());
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":1} trailing").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn arbitrary_nested_values_roundtrip_emit_parse_emit() {
        use crate::util::prng::Rng;
        use crate::util::prop::{check, default_cases, ensure};

        fn arb_string(rng: &mut Rng) -> String {
            let n = rng.below(9) as usize;
            (0..n)
                .map(|_| match rng.below(12) {
                    0 => '"',
                    1 => '\\',
                    2 => '\n',
                    3 => '\t',
                    4 => '\u{1}', // control char: emits as \u0001
                    5 => 'é',     // 2-byte UTF-8
                    6 => '✓',     // 3-byte UTF-8
                    7 => '𝕏',     // 4-byte UTF-8 (astral plane)
                    _ => (b'a' + rng.below(26) as u8) as char,
                })
                .collect()
        }

        fn leaf(rng: &mut Rng) -> Json {
            match rng.below(5) {
                0 => Json::Null,
                1 => Json::Bool(rng.below(2) == 0),
                2 => Json::Int(rng.below(2_000_001) as i64 - 1_000_000),
                // odd multiples of 1/16: never integral, so Display
                // keeps a fraction and the reparse stays an equal Num
                // (an integral Num would reparse as Int — the text
                // would still be stable, but not the value)
                3 => Json::Num((rng.below(2_000_000) as f64 - 1e6 + 0.5) / 8.0),
                _ => Json::Str(arb_string(rng)),
            }
        }

        fn arb_value(rng: &mut Rng, depth: u32) -> Json {
            if depth == 0 {
                return leaf(rng);
            }
            match rng.below(4) {
                0 | 1 => leaf(rng),
                2 => {
                    let n = rng.below(5) as usize;
                    Json::Arr((0..n).map(|_| arb_value(rng, depth - 1)).collect())
                }
                _ => {
                    let n = rng.below(5) as usize;
                    Json::Obj(
                        (0..n)
                            .map(|i| (format!("{}{i}", arb_string(rng)), arb_value(rng, depth - 1)))
                            .collect(),
                    )
                }
            }
        }

        check(
            "json emit-parse-emit",
            default_cases(),
            |rng, _| arb_value(rng, 4),
            |v| {
                let text = v.to_string();
                let back =
                    Json::parse(&text).map_err(|e| format!("reparse failed on {text}: {e}"))?;
                ensure(back == *v, format!("value drifted via {text}: {back:?} vs {v:?}"))?;
                ensure(back.to_string() == text, format!("re-emit drifted for {text}"))
            },
        );
    }

    #[test]
    fn accessors_are_type_safe() {
        let j = Json::obj().field("n", 3usize).field("s", "str");
        assert_eq!(j.get("n").and_then(|v| v.as_i64()), Some(3));
        assert_eq!(j.get("n").and_then(|v| v.as_f64()), Some(3.0));
        assert!(j.get("n").unwrap().as_str().is_none());
        assert!(j.get("s").unwrap().as_i64().is_none());
        assert!(j.get("missing").is_none());
        assert!(Json::Int(1).get("x").is_none());
        assert_eq!(j.as_obj().unwrap().len(), 2);
    }
}
