//! Small self-contained utilities substituting for crates that are not
//! available in the offline vendor set (clap, criterion, proptest, serde).

pub mod backoff;
pub mod bench;
pub mod cli;
pub mod fault;
pub mod fnv;
pub mod json;
pub mod prng;
pub mod prop;
pub mod stats;
