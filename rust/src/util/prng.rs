//! Deterministic PRNG (SplitMix64 + xoshiro256**) — substitute for the
//! `rand` crate in the offline environment. Used by tests, the property
//! harness and the workload generators. Reproducibility matters more than
//! statistical perfection here, but xoshiro256** passes BigCrush.

/// SplitMix64 — used to seed xoshiro and as a tiny standalone generator.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — the main generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform u32.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)` (Lemire's method, no modulo bias for our use).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        if n == 0 {
            return 0;
        }
        // 128-bit multiply trick
        let x = self.next_u64();
        ((x as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform i8 over the full range.
    #[inline]
    pub fn i8(&mut self) -> i8 {
        self.next_u64() as i8
    }

    /// Uniform i8 in `[lo, hi]` inclusive.
    #[inline]
    pub fn i8_range(&mut self, lo: i8, hi: i8) -> i8 {
        let span = (hi as i64 - lo as i64 + 1) as u64;
        (lo as i64 + self.below(span) as i64) as i8
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.f64() as f32) * (hi - lo)
    }

    /// Standard normal via Box–Muller (one value per call; simple, fine
    /// for weight init in tests and examples).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fill a slice with uniform i8 values in `[lo, hi]`.
    pub fn fill_i8(&mut self, buf: &mut [i8], lo: i8, hi: i8) {
        for b in buf.iter_mut() {
            *b = self.i8_range(lo, hi);
        }
    }

    /// Vector of f32 drawn from N(0, sigma).
    pub fn normal_vec_f32(&mut self, n: usize, sigma: f32) -> Vec<f32> {
        (0..n).map(|_| (self.normal() as f32) * sigma).collect()
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn i8_range_bounds() {
        let mut r = Rng::new(9);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..5000 {
            let v = r.i8_range(-3, 5);
            assert!((-3..=5).contains(&v));
            lo_seen |= v == -3;
            hi_seen |= v == 5;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(11);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_mean_near_zero() {
        let mut r = Rng::new(13);
        let n = 20000;
        let mean: f64 = (0..n).map(|_| r.normal()).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }
}
