//! Miniature property-based-testing harness (proptest is not in the offline
//! vendor set). Provides `check`: run a property over N randomly generated
//! cases with a deterministic seed; on failure, report the case index and
//! seed so the exact case can be replayed.
//!
//! Shrinking is deliberately not implemented — generators here draw small
//! sizes to begin with, which keeps failing cases readable.

use super::prng::Rng;

/// Number of cases per property (override with CONVBENCH_PROP_CASES).
pub fn default_cases() -> usize {
    std::env::var("CONVBENCH_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` over `cases` generated inputs. `gen` receives a seeded RNG
/// and the case index. `prop` returns `Err(msg)` to fail.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Rng, usize) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let seed = 0xC0FFEE ^ name.len() as u64;
    for i in 0..cases {
        let mut rng = Rng::new(seed.wrapping_add(i as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let case = gen(&mut rng, i);
        if let Err(msg) = prop(&case) {
            panic!(
                "property '{name}' failed at case {i}/{cases} (seed base {seed:#x}):\n  \
                 {msg}\n  case: {case:?}"
            );
        }
    }
}

/// Convenience assertion helpers for property bodies.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Assert two i8 slices are identical, reporting the first mismatch.
pub fn ensure_eq_i8(a: &[i8], b: &[i8], what: &str) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("{what}: length {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        if x != y {
            return Err(format!("{what}: first mismatch at [{i}]: {x} vs {y}"));
        }
    }
    Ok(())
}

/// Assert two i32 slices are identical, reporting the first mismatch.
pub fn ensure_eq_i32(a: &[i32], b: &[i32], what: &str) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("{what}: length {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        if x != y {
            return Err(format!("{what}: first mismatch at [{i}]: {x} vs {y}"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check(
            "count",
            10,
            |rng, _| rng.range(0, 100),
            |_| {
                count += 1;
                Ok(())
            },
        );
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "property 'bad' failed")]
    fn failing_property_panics_with_case() {
        check(
            "bad",
            10,
            |rng, _| rng.range(0, 100),
            |_| Err("always fails".to_string()),
        );
    }

    #[test]
    fn ensure_eq_reports_index() {
        let e = ensure_eq_i8(&[1, 2, 3], &[1, 9, 3], "x").unwrap_err();
        assert!(e.contains("[1]"), "{e}");
    }

    #[test]
    fn generation_is_deterministic() {
        let mut first: Vec<usize> = Vec::new();
        check(
            "det",
            5,
            |rng, _| rng.range(0, 1000),
            |v| {
                first.push(*v);
                Ok(())
            },
        );
        let mut second: Vec<usize> = Vec::new();
        check(
            "det",
            5,
            |rng, _| rng.range(0, 1000),
            |v| {
                second.push(*v);
                Ok(())
            },
        );
        assert_eq!(first, second);
    }
}
