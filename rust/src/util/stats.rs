//! Statistics helpers: ordinary-least-squares linear regression (used for
//! the paper's R² linearity claims in §4.1), summary statistics for the
//! bench runner, and small helpers shared by the harness.

/// Result of a simple linear regression `y = a·x + b`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinearFit {
    /// Slope.
    pub a: f64,
    /// Intercept.
    pub b: f64,
    /// Coefficient of determination (the paper reports these as
    /// "regression scores", e.g. 0.995 MACs↔latency without SIMD).
    pub r2: f64,
    /// Number of points.
    pub n: usize,
}

/// Ordinary least squares on `(x, y)` pairs.
///
/// Returns `None` for fewer than 2 points or a degenerate x variance.
pub fn linreg(xs: &[f64], ys: &[f64]) -> Option<LinearFit> {
    let n = xs.len().min(ys.len());
    if n < 2 {
        return None;
    }
    let nf = n as f64;
    let mx = xs[..n].iter().sum::<f64>() / nf;
    let my = ys[..n].iter().sum::<f64>() / nf;
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    let mut syy = 0.0;
    for i in 0..n {
        let dx = xs[i] - mx;
        let dy = ys[i] - my;
        sxx += dx * dx;
        sxy += dx * dy;
        syy += dy * dy;
    }
    if sxx <= f64::EPSILON {
        return None;
    }
    let a = sxy / sxx;
    let b = my - a * mx;
    // R² = 1 - SS_res / SS_tot
    let mut ss_res = 0.0;
    for i in 0..n {
        let e = ys[i] - (a * xs[i] + b);
        ss_res += e * e;
    }
    let r2 = if syy <= f64::EPSILON { 1.0 } else { 1.0 - ss_res / syy };
    Some(LinearFit { a, b, r2, n })
}

/// Pearson correlation coefficient.
pub fn pearson(xs: &[f64], ys: &[f64]) -> Option<f64> {
    linreg(xs, ys).map(|f| f.r2.sqrt() * f.a.signum())
}

/// Summary statistics over a sample (used by the bench runner).
#[derive(Clone, Copy, Debug)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
}

/// Compute summary statistics. Returns `None` on an empty sample.
pub fn summarize(sample: &[f64]) -> Option<Summary> {
    if sample.is_empty() {
        return None;
    }
    let n = sample.len();
    let mean = sample.iter().sum::<f64>() / n as f64;
    let var = if n > 1 {
        sample.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
    } else {
        0.0
    };
    let mut sorted = sample.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = if n % 2 == 1 {
        sorted[n / 2]
    } else {
        0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
    };
    Some(Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        median,
    })
}

/// Geometric mean of strictly-positive values.
pub fn geomean(xs: &[f64]) -> Option<f64> {
    if xs.is_empty() || xs.iter().any(|&x| x <= 0.0) {
        return None;
    }
    Some((xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_line_r2_is_one() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x + 1.0).collect();
        let f = linreg(&xs, &ys).unwrap();
        assert!((f.a - 3.0).abs() < 1e-12);
        assert!((f.b - 1.0).abs() < 1e-12);
        assert!((f.r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn noisy_line_r2_below_one() {
        let xs: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs
            .iter()
            .enumerate()
            .map(|(i, x)| 2.0 * x + if i % 2 == 0 { 1.0 } else { -1.0 })
            .collect();
        let f = linreg(&xs, &ys).unwrap();
        assert!(f.r2 < 1.0 && f.r2 > 0.9);
    }

    #[test]
    fn degenerate_inputs() {
        assert!(linreg(&[1.0], &[2.0]).is_none());
        assert!(linreg(&[1.0, 1.0, 1.0], &[1.0, 2.0, 3.0]).is_none());
    }

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.median - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!(summarize(&[]).is_none());
    }

    #[test]
    fn geomean_of_constant() {
        assert!((geomean(&[2.0, 2.0, 2.0]).unwrap() - 2.0).abs() < 1e-12);
        assert!(geomean(&[1.0, 0.0]).is_none());
    }

    #[test]
    fn pearson_sign() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let down: Vec<f64> = xs.iter().map(|x| -x).collect();
        assert!(pearson(&xs, &down).unwrap() < -0.999);
    }
}
