//! Statistics helpers: ordinary-least-squares linear regression (used for
//! the paper's R² linearity claims in §4.1), summary statistics for the
//! bench runner, and small helpers shared by the harness.

/// Result of a simple linear regression `y = a·x + b`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinearFit {
    /// Slope.
    pub a: f64,
    /// Intercept.
    pub b: f64,
    /// Coefficient of determination (the paper reports these as
    /// "regression scores", e.g. 0.995 MACs↔latency without SIMD).
    pub r2: f64,
    /// Number of points.
    pub n: usize,
}

/// Ordinary least squares on `(x, y)` pairs.
///
/// Returns `None` for fewer than 2 points or a degenerate x variance.
pub fn linreg(xs: &[f64], ys: &[f64]) -> Option<LinearFit> {
    let n = xs.len().min(ys.len());
    if n < 2 {
        return None;
    }
    let nf = n as f64;
    let mx = xs[..n].iter().sum::<f64>() / nf;
    let my = ys[..n].iter().sum::<f64>() / nf;
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    let mut syy = 0.0;
    for i in 0..n {
        let dx = xs[i] - mx;
        let dy = ys[i] - my;
        sxx += dx * dx;
        sxy += dx * dy;
        syy += dy * dy;
    }
    if sxx <= f64::EPSILON {
        return None;
    }
    let a = sxy / sxx;
    let b = my - a * mx;
    // R² = 1 - SS_res / SS_tot
    let mut ss_res = 0.0;
    for i in 0..n {
        let e = ys[i] - (a * xs[i] + b);
        ss_res += e * e;
    }
    let r2 = if syy <= f64::EPSILON { 1.0 } else { 1.0 - ss_res / syy };
    Some(LinearFit { a, b, r2, n })
}

/// Pearson correlation coefficient.
pub fn pearson(xs: &[f64], ys: &[f64]) -> Option<f64> {
    linreg(xs, ys).map(|f| f.r2.sqrt() * f.a.signum())
}

/// Summary statistics over a sample (used by the bench runner).
#[derive(Clone, Copy, Debug)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
}

/// Compute summary statistics. Returns `None` on an empty sample.
pub fn summarize(sample: &[f64]) -> Option<Summary> {
    if sample.is_empty() {
        return None;
    }
    let n = sample.len();
    let mean = sample.iter().sum::<f64>() / n as f64;
    let var = if n > 1 {
        sample.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
    } else {
        0.0
    };
    let mut sorted = sample.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = if n % 2 == 1 {
        sorted[n / 2]
    } else {
        0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
    };
    Some(Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        median,
    })
}

/// Geometric mean of strictly-positive values.
pub fn geomean(xs: &[f64]) -> Option<f64> {
    if xs.is_empty() || xs.iter().any(|&x| x <= 0.0) {
        return None;
    }
    Some((xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp())
}

/// Fixed-capacity uniform reservoir (Vitter's Algorithm R), seeded and
/// deterministic via [`crate::util::prng::Rng`]. Under capacity it keeps
/// every sample verbatim — summaries over a short history are exact —
/// and past capacity each of the `seen` values has equal probability of
/// being retained, so a long-lived consumer (the inference server's
/// latency statistics) holds O(capacity) memory under unbounded traffic.
#[derive(Debug)]
pub struct Reservoir {
    cap: usize,
    seen: u64,
    /// Exact running sum over ALL offered values (the mean never needs
    /// to be approximated — only order statistics do).
    sum: f64,
    rng: crate::util::prng::Rng,
    samples: Vec<f64>,
}

impl Reservoir {
    pub fn new(cap: usize, seed: u64) -> Self {
        assert!(cap > 0, "reservoir capacity must be positive");
        Self {
            cap,
            seen: 0,
            sum: 0.0,
            rng: crate::util::prng::Rng::new(seed),
            samples: Vec::with_capacity(cap),
        }
    }

    /// Offer one observation. O(1), allocation-free once the reservoir
    /// has filled its pre-reserved capacity.
    pub fn offer(&mut self, v: f64) {
        self.seen += 1;
        self.sum += v;
        if self.samples.len() < self.cap {
            self.samples.push(v);
        } else {
            // Algorithm R: replace a random slot with probability cap/seen
            let j = self.rng.below(self.seen);
            if (j as usize) < self.cap {
                self.samples[j as usize] = v;
            }
        }
    }

    /// Total observations offered (≥ retained sample count).
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// Exact mean over every value ever offered (not a subsample
    /// estimate). 0.0 before the first observation.
    pub fn mean(&self) -> f64 {
        if self.seen == 0 {
            0.0
        } else {
            self.sum / self.seen as f64
        }
    }

    /// Retained sample count (== min(seen, capacity)).
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Merge another reservoir into this one (cross-worker stat
    /// aggregation — the inference server merges per-worker shards at
    /// scrape time).
    ///
    /// The exact quantities stay exact: `seen` and the running sum add,
    /// so [`Reservoir::mean`] after a merge equals the mean over the
    /// union of both full streams. The retained subsample follows a
    /// deterministic policy: `other`'s retained samples are offered
    /// through this reservoir's seeded Algorithm-R machinery — appended
    /// verbatim while under capacity, then each replaces a
    /// PRNG-selected slot with probability `cap / seen_so_far` against
    /// the already-merged population. Order statistics remain subsample
    /// estimates exactly as for a single reservoir, results are
    /// identical across runs for identical inputs, and capacity never
    /// regrows.
    pub fn merge(&mut self, other: &Reservoir) {
        self.sum += other.sum;
        self.seen += other.seen;
        for &v in other.samples() {
            if self.samples.len() < self.cap {
                self.samples.push(v);
            } else {
                let j = self.rng.below(self.seen);
                if (j as usize) < self.cap {
                    self.samples[j as usize] = v;
                }
            }
        }
    }

    /// The retained samples (unordered).
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    /// Mutable view for in-place summarization (e.g. a sorting
    /// percentile pass) — reordering does not bias the reservoir.
    pub fn samples_mut(&mut self) -> &mut [f64] {
        &mut self.samples
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_line_r2_is_one() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x + 1.0).collect();
        let f = linreg(&xs, &ys).unwrap();
        assert!((f.a - 3.0).abs() < 1e-12);
        assert!((f.b - 1.0).abs() < 1e-12);
        assert!((f.r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn noisy_line_r2_below_one() {
        let xs: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs
            .iter()
            .enumerate()
            .map(|(i, x)| 2.0 * x + if i % 2 == 0 { 1.0 } else { -1.0 })
            .collect();
        let f = linreg(&xs, &ys).unwrap();
        assert!(f.r2 < 1.0 && f.r2 > 0.9);
    }

    #[test]
    fn degenerate_inputs() {
        assert!(linreg(&[1.0], &[2.0]).is_none());
        assert!(linreg(&[1.0, 1.0, 1.0], &[1.0, 2.0, 3.0]).is_none());
    }

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.median - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!(summarize(&[]).is_none());
    }

    #[test]
    fn geomean_of_constant() {
        assert!((geomean(&[2.0, 2.0, 2.0]).unwrap() - 2.0).abs() < 1e-12);
        assert!(geomean(&[1.0, 0.0]).is_none());
    }

    #[test]
    fn pearson_sign() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let down: Vec<f64> = xs.iter().map(|x| -x).collect();
        assert!(pearson(&xs, &down).unwrap() < -0.999);
    }

    #[test]
    fn reservoir_is_exact_under_capacity() {
        let mut r = Reservoir::new(100, 7);
        for v in 1..=60 {
            r.offer(v as f64);
        }
        assert_eq!(r.seen(), 60);
        assert_eq!(r.len(), 60);
        // verbatim history: every offered value retained, in order
        let want: Vec<f64> = (1..=60).map(|v| v as f64).collect();
        assert_eq!(r.samples(), &want[..]);
        assert!((r.mean() - 30.5).abs() < 1e-12);
    }

    #[test]
    fn reservoir_mean_is_exact_past_capacity() {
        let mut r = Reservoir::new(8, 3);
        for v in 1..=1000 {
            r.offer(v as f64);
        }
        // the retained set is a subsample, but the mean is the stream's
        assert_eq!(r.len(), 8);
        assert!((r.mean() - 500.5).abs() < 1e-9);
        assert_eq!(Reservoir::new(4, 1).mean(), 0.0);
    }

    #[test]
    fn reservoir_memory_is_bounded_and_deterministic() {
        let cap = 256;
        let n = 100_000u64;
        let mut a = Reservoir::new(cap, 0x5EED);
        let mut b = Reservoir::new(cap, 0x5EED);
        let cap0 = a.samples.capacity();
        for v in 0..n {
            a.offer(v as f64);
            b.offer(v as f64);
        }
        assert_eq!(a.len(), cap);
        assert_eq!(a.seen(), n);
        assert_eq!(a.samples.capacity(), cap0, "reservoir must never regrow");
        // seeded PRNG → identical retained set on identical input
        assert_eq!(a.samples(), b.samples());
        // the retained set stays representative of the uniform stream:
        // its mean lands near the stream mean
        let mean = a.samples().iter().sum::<f64>() / cap as f64;
        let stream_mean = (n - 1) as f64 / 2.0;
        assert!(
            (mean - stream_mean).abs() < 0.15 * stream_mean,
            "reservoir mean {mean} vs stream mean {stream_mean}"
        );
    }

    #[test]
    #[should_panic(expected = "capacity must be positive")]
    fn reservoir_rejects_zero_capacity() {
        Reservoir::new(0, 1);
    }

    #[test]
    fn reservoir_merge_mean_is_exact_past_capacity() {
        let mut a = Reservoir::new(8, 1);
        for v in 1..=100 {
            a.offer(v as f64);
        }
        let mut b = Reservoir::new(8, 2);
        for v in 101..=300 {
            b.offer(v as f64);
        }
        a.merge(&b);
        // both reservoirs are far past capacity, yet the merged mean is
        // the exact mean of the union of both streams
        assert_eq!(a.seen(), 300);
        assert!((a.mean() - 150.5).abs() < 1e-9);
        assert_eq!(a.len(), 8, "merge must not grow the retained set");
    }

    #[test]
    fn reservoir_merge_handles_empty_edges() {
        // empty into empty
        let mut a = Reservoir::new(4, 1);
        a.merge(&Reservoir::new(4, 2));
        assert_eq!(a.seen(), 0);
        assert_eq!(a.mean(), 0.0);
        assert!(a.is_empty());
        // non-empty into empty: retained verbatim, mean exact
        a.offer(2.0);
        a.offer(4.0);
        let mut c = Reservoir::new(4, 3);
        c.merge(&a);
        assert_eq!(c.samples(), &[2.0, 4.0]);
        assert!((c.mean() - 3.0).abs() < 1e-12);
        // empty other is a no-op
        c.merge(&Reservoir::new(4, 4));
        assert_eq!(c.seen(), 2);
        assert_eq!(c.samples(), &[2.0, 4.0]);
    }

    #[test]
    fn reservoir_merge_is_deterministic_and_bounded() {
        let build = || {
            let mut r = Reservoir::new(4, 10);
            let mut big = Reservoir::new(4, 11);
            for v in 0..1000 {
                big.offer(v as f64);
            }
            r.offer(1.0);
            r.merge(&big);
            r
        };
        let x = build();
        let y = build();
        assert_eq!(x.samples(), y.samples(), "seeded merge must be reproducible");
        assert_eq!(x.seen(), 1001);
        assert_eq!(x.len(), 4);
        let cap0 = x.samples.capacity();
        let mut z = build();
        z.merge(&build());
        assert_eq!(z.samples.capacity(), cap0, "merge must never regrow capacity");
    }
}
