//! Integration: the joint graph tuner and its latency↔RAM Pareto
//! frontier — the acceptance criteria of the budgeted-deployment
//! subsystem:
//!
//! 1. the greedy tuner's RAM report is the liveness truth: on the
//!    residual zoo, every per-node `ram_bytes` equals the compiled
//!    plan's per-step arena high-water plus that node's scratch (the
//!    old input+output sum over-priced residual joins);
//! 2. the unbudgeted joint search is never worse than greedy on any
//!    zoo model under either backend policy (it *is* the same
//!    argmin — asserted schedule-for-schedule);
//! 3. every frontier point compiles into a plan whose workspace covers
//!    the point's claimed peak, the claim fits the point's threshold,
//!    and every point's logits are bit-exact with the reference —
//!    vec-backend points included;
//! 4. on a residual model with a budget below the unconstrained
//!    optimum's peak, the joint search finds a feasible schedule
//!    within 25% of the unconstrained latency (the greedy choice is
//!    infeasible there by construction).

use convbench::analytic::Primitive;
use convbench::mcu::McuConfig;
use convbench::models::{mcunet, mcunet_residual};
use convbench::nn::{Graph, NoopMonitor, Tensor};
use convbench::tuner::{
    schedule_from_candidates, tune_graph_budgeted, tune_graph_frontier, tune_graph_joint,
    tune_graph_shape_backend, BackendSel, Objective, TuningCache,
};
use convbench::util::prng::Rng;

fn zoo() -> Vec<Graph> {
    Primitive::ALL
        .iter()
        .map(|&p| Graph::from_model(&mcunet(p, 42)))
        .chain(Primitive::ALL.iter().map(|&p| mcunet_residual(p, 42)))
        .collect()
}

#[test]
fn greedy_ram_report_matches_compiled_plan_on_residual_graphs() {
    // the satellite-1 regression: `ram_bytes` must be what `plan_arena`
    // actually packs, not the node-local in+out+scratch sum — on
    // `mcunet-res-*` the residual join's operands share liveness with
    // the skip value, so the two models genuinely differ
    let cfg = McuConfig::default();
    let mut cache = TuningCache::in_memory();
    for prim in Primitive::ALL {
        let graph = mcunet_residual(prim, 42);
        let (sched, _) = tune_graph_shape_backend(
            &graph,
            &cfg,
            Objective::Latency,
            BackendSel::Auto,
            &mut cache,
        );
        let plan = sched.compile_graph(&graph);
        for (i, d) in sched.layers.iter().enumerate() {
            assert_eq!(
                d.ram_bytes,
                plan.step_live_bytes(i) + plan.layer_scratch_bytes(i),
                "{}: node {i} RAM report drifted from the compiled arena",
                graph.name
            );
        }
        let engine_peak = (0..plan.n_layers())
            .map(|i| plan.step_live_bytes(i) + plan.layer_scratch_bytes(i))
            .max()
            .unwrap();
        assert_eq!(sched.peak_ram_bytes, engine_peak, "{}", graph.name);
        // and the compiled workspace still covers the claim
        let ws = sched.workspace_graph(&graph);
        assert!(ws.plan().total_bytes() >= sched.peak_ram_bytes, "{}", graph.name);
    }
}

#[test]
fn unbudgeted_joint_search_equals_greedy_on_every_zoo_model() {
    let cfg = McuConfig::default();
    for backend in [BackendSel::Scalar, BackendSel::Vec, BackendSel::Auto] {
        for graph in zoo() {
            let mut c1 = TuningCache::in_memory();
            let mut c2 = TuningCache::in_memory();
            let (greedy, _) =
                tune_graph_shape_backend(&graph, &cfg, Objective::Latency, backend, &mut c1);
            let (joint, _) =
                tune_graph_joint(&graph, &cfg, Objective::Latency, backend, None, &mut c2);
            let joint = joint.expect("budget-free joint search always succeeds");
            assert!(
                joint.latency_s <= greedy.latency_s + 1e-12,
                "{} [{backend:?}]: joint {} s > greedy {} s",
                graph.name,
                joint.latency_s,
                greedy.latency_s
            );
            // they are in fact the same argmin, decision for decision
            assert_eq!(joint.candidates(), greedy.candidates(), "{} [{backend:?}]", graph.name);
            assert_eq!(joint.peak_ram_bytes, greedy.peak_ram_bytes);
        }
    }
}

#[test]
fn every_frontier_point_compiles_within_its_claim_and_stays_bit_exact() {
    let cfg = McuConfig::default();
    let mut rng = Rng::new(0xF407);
    let mut saw_vec_point = false;
    for graph in zoo() {
        let mut cache = TuningCache::in_memory();
        let (frontier, _) =
            tune_graph_frontier(&graph, &cfg, Objective::Latency, BackendSel::Auto, &mut cache);
        assert!(!frontier.is_empty(), "{}", graph.name);
        let mut x = Tensor::zeros(graph.input_shape, graph.input_q);
        rng.fill_i8(&mut x.data, -96, 95);
        let want = graph.forward(&x, true, &mut NoopMonitor);
        for p in &frontier.points {
            let sched = schedule_from_candidates(&graph, &p.candidates, &cfg, Objective::Latency);
            // the materialized schedule re-derives exactly the frontier
            // point's claim
            assert_eq!(sched.peak_ram_bytes, p.peak_ram_bytes, "{}", graph.name);
            // workspace ≥ claimed peak, and the claim fits the
            // threshold the point was searched under
            let ws = sched.workspace_graph(&graph);
            assert!(
                ws.plan().total_bytes() >= p.peak_ram_bytes,
                "{}: workspace {} B < claimed peak {} B",
                graph.name,
                ws.plan().total_bytes(),
                p.peak_ram_bytes
            );
            // bit-exact across the whole frontier (vec points included)
            let got = sched.run_graph(&graph, &x, &mut NoopMonitor);
            assert_eq!(want.data, got.data, "{} @ {} B", graph.name, p.peak_ram_bytes);
            saw_vec_point |= p
                .candidates
                .iter()
                .any(|c| c.backend == convbench::nn::Backend::VecLanes);
        }
    }
    assert!(saw_vec_point, "auto policy never deployed a vec kernel anywhere in the zoo");
}

#[test]
fn budgeted_joint_tune_beats_infeasible_greedy_on_a_residual_model() {
    // the PR's acceptance scenario: a budget below the unconstrained
    // optimum's peak, where greedy's choice does not fit, but the joint
    // search still finds a schedule within 25% of the unconstrained
    // latency. At least one residual zoo model must expose such a
    // budget (a frontier with a single point would make every budget
    // either trivial or infeasible).
    let cfg = McuConfig::default();
    let mut demonstrated = 0usize;
    for prim in Primitive::ALL {
        let graph = mcunet_residual(prim, 42);
        let mut cache = TuningCache::in_memory();
        let (greedy, _) = tune_graph_shape_backend(
            &graph,
            &cfg,
            Objective::Latency,
            BackendSel::Auto,
            &mut cache,
        );
        let (frontier, _) =
            tune_graph_frontier(&graph, &cfg, Objective::Latency, BackendSel::Auto, &mut cache);
        // tightest budget strictly below the greedy optimum's peak
        let Some(budget) = frontier
            .points
            .iter()
            .map(|p| p.peak_ram_bytes)
            .filter(|&b| b < greedy.peak_ram_bytes)
            .max()
        else {
            continue;
        };
        // greedy's schedule is infeasible at this budget by construction
        assert!(greedy.peak_ram_bytes > budget);
        let (sched, _) = tune_graph_joint(
            &graph,
            &cfg,
            Objective::Latency,
            BackendSel::Auto,
            Some(budget),
            &mut cache,
        );
        let sched = sched.unwrap_or_else(|| {
            panic!("{}: joint search infeasible at budget {budget} B", graph.name)
        });
        assert!(sched.peak_ram_bytes <= budget, "{}", graph.name);
        if sched.latency_s <= greedy.latency_s * 1.25 {
            demonstrated += 1;
        }
        // the frontier's own selection must agree with the joint search
        let (via_frontier, _) = tune_graph_budgeted(
            &graph,
            &cfg,
            Objective::Latency,
            BackendSel::Auto,
            budget,
            &mut cache,
        );
        let via_frontier = via_frontier.expect("frontier point exists at this budget");
        assert_eq!(via_frontier.candidates(), sched.candidates(), "{}", graph.name);
    }
    assert!(
        demonstrated >= 1,
        "no residual zoo model demonstrates a feasible sub-greedy-peak budget \
         within 25% of the unconstrained latency"
    );
}
