//! Cross-PR golden vectors: the whole model zoo's logits, pinned to a
//! committed file.
//!
//! Every other numeric test in the repo compares the engine against
//! *itself* (scalar vs vec, graph vs plan, dense-with-zeroed-channels vs
//! compacted). Those catch within-PR regressions but are blind to a
//! change that shifts *all* paths together — a requantization tweak, a
//! reordered accumulation, a new rounding mode. This suite pins the
//! absolute numbers across PRs: each zoo model's logits on a fixed
//! input, stored in `rust/tests/golden/zoo.json` and committed.
//!
//! Workflow (see `rust/tests/golden/README.md`):
//! * the golden file exists → every model's logits must match it
//!   bit-for-bit, every model in the file must still exist, and every
//!   zoo model must have an entry — any mismatch fails with the diff;
//! * the golden file is missing, or `CONVBENCH_BLESS=1` → the suite
//!   regenerates and writes it, then passes. **Commit the file**: an
//!   uncommitted golden file pins nothing.
//!
//! An intentional numeric change re-blesses in one command
//! (`CONVBENCH_BLESS=1 cargo test --test integration_golden`) and the
//! file's diff becomes part of the PR review.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use convbench::models::zoo_graphs;
use convbench::nn::{Graph, NoopMonitor, Tensor};
use convbench::tuner::{tune_graph_shape_backend, BackendSel, Objective, TuningCache};
use convbench::util::fnv::Fnv1a;
use convbench::util::json::Json;
use convbench::util::prng::Rng;

/// Seed for the zoo builds. Must never change: the golden vectors are a
/// function of it.
const ZOO_SEED: u64 = 42;

/// Golden file format version (bumped only if the schema changes, not
/// when vectors are re-blessed).
const GOLDEN_VERSION: i64 = 1;

fn golden_path() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden/zoo.json")
}

/// The fixed input for one model: seeded from the model *name*, so
/// adding zoo members never shifts the inputs of existing ones.
fn golden_input(graph: &Graph) -> Tensor {
    let mut h = Fnv1a::new();
    for b in graph.name.bytes() {
        h.byte(b);
    }
    let mut x = Tensor::zeros(graph.input_shape, graph.input_q);
    Rng::new(h.finish() ^ 0x601D_E41).fill_i8(&mut x.data, -96, 95);
    x
}

/// Compute the current logits for every zoo model. The reference value
/// is the plain simd graph forward; the scalar forward and the tuned
/// compiled plan must agree with it before anything is compared against
/// the golden file — a golden mismatch should always mean "the numbers
/// moved", never "the paths disagree".
fn current_vectors() -> BTreeMap<String, Vec<i8>> {
    let cfg = convbench::mcu::McuConfig::default();
    let mut cache = TuningCache::in_memory();
    let mut out = BTreeMap::new();
    for graph in zoo_graphs(ZOO_SEED) {
        let x = golden_input(&graph);
        let want = graph.forward(&x, true, &mut NoopMonitor);
        let scalar = graph.forward(&x, false, &mut NoopMonitor);
        assert_eq!(
            want.data, scalar.data,
            "{}: scalar and simd forwards disagree — fix parity before blessing goldens",
            graph.name
        );
        let (sched, _) = tune_graph_shape_backend(
            &graph,
            &cfg,
            Objective::Latency,
            BackendSel::Auto,
            &mut cache,
        );
        let tuned = sched.run_graph(&graph, &x, &mut NoopMonitor);
        assert_eq!(
            want.data, tuned.data,
            "{}: tuned plan disagrees with the graph forward",
            graph.name
        );
        let prev = out.insert(graph.name.clone(), want.data);
        assert!(prev.is_none(), "duplicate zoo model name {}", graph.name);
    }
    out
}

fn vectors_to_json(vectors: &BTreeMap<String, Vec<i8>>) -> Json {
    let mut models = Json::obj();
    for (name, logits) in vectors {
        let arr: Vec<i64> = logits.iter().map(|&v| v as i64).collect();
        models = models.field(name, arr);
    }
    Json::obj()
        .field("version", GOLDEN_VERSION)
        .field("zoo_seed", ZOO_SEED)
        .field("models", models)
}

fn vectors_from_json(json: &Json) -> Result<BTreeMap<String, Vec<i8>>, String> {
    if json.get("version").and_then(|v| v.as_i64()) != Some(GOLDEN_VERSION) {
        return Err("golden file version mismatch — delete and re-bless".into());
    }
    if json.get("zoo_seed").and_then(|v| v.as_i64()) != Some(ZOO_SEED as i64) {
        return Err("golden file zoo seed mismatch — delete and re-bless".into());
    }
    let models = json
        .get("models")
        .and_then(|m| m.as_obj())
        .ok_or("golden file has no models object")?;
    let mut out = BTreeMap::new();
    for (name, arr) in models {
        let items = arr.as_arr().ok_or_else(|| format!("{name}: logits not an array"))?;
        let mut logits = Vec::with_capacity(items.len());
        for v in items {
            let i = v.as_i64().ok_or_else(|| format!("{name}: non-integer logit entry"))?;
            logits.push(i as i8);
        }
        out.insert(name.clone(), logits);
    }
    Ok(out)
}

#[test]
fn zoo_logits_match_the_committed_golden_vectors() {
    let current = current_vectors();
    // the zoo must actually cover dense, residual and pruned variants —
    // a silently-shrunk zoo would weaken the pin without failing it
    assert!(
        current.keys().any(|n| n.contains("-res-")),
        "zoo lost its residual variants"
    );
    assert!(
        current.keys().any(|n| n.contains("-pruned")),
        "zoo lost its pruned variants"
    );
    assert!(current.len() >= 40, "zoo shrank to {} models", current.len());

    let path = golden_path();
    let bless = std::env::var("CONVBENCH_BLESS").map(|v| v == "1").unwrap_or(false);
    if bless || !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap()).expect("create golden dir");
        std::fs::write(&path, vectors_to_json(&current).to_string()).expect("write golden file");
        println!(
            "blessed {} golden vectors to {} — commit this file to pin them across PRs",
            current.len(),
            path.display()
        );
        return;
    }

    let text = std::fs::read_to_string(&path).expect("read golden file");
    let golden = vectors_from_json(&Json::parse(&text).expect("parse golden file"))
        .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
    let mut drifted = Vec::new();
    for (name, want) in &golden {
        match current.get(name) {
            None => drifted.push(format!("{name}: in golden file but no longer in the zoo")),
            Some(got) if got != want => {
                let first = want
                    .iter()
                    .zip(got.iter())
                    .position(|(a, b)| a != b)
                    .unwrap_or(usize::MAX);
                drifted.push(format!(
                    "{name}: logits drifted (first diff at index {first}: golden {:?} vs current \
                     {:?})",
                    want.get(first),
                    got.get(first)
                ));
            }
            Some(_) => {}
        }
    }
    for name in current.keys() {
        if !golden.contains_key(name) {
            drifted.push(format!(
                "{name}: new zoo model without a golden entry — re-bless with CONVBENCH_BLESS=1"
            ));
        }
    }
    assert!(
        drifted.is_empty(),
        "golden vectors drifted ({} models):\n  {}\nIf the numeric change is intentional, \
         re-bless with CONVBENCH_BLESS=1 and commit the updated {}",
        drifted.len(),
        drifted.join("\n  "),
        path.display()
    );
}

#[test]
fn golden_inputs_are_stable_functions_of_the_model_name() {
    // the input derivation is part of the cross-PR contract: it must
    // depend on the model name only, not on zoo order or count
    let zoo = zoo_graphs(ZOO_SEED);
    let a = golden_input(&zoo[0]);
    let b = golden_input(&zoo[0]);
    assert_eq!(a.data, b.data);
    let other = golden_input(&zoo[1]);
    assert_ne!(a.data, other.data, "two models drew the same golden input");
}
