//! Integration: artifacts → PJRT runtime → bit-exact parity with the
//! int8 engine, for every primitive (the cross-layer contract).
//!
//! Environment-gated twice over: the whole file needs the `pjrt` cargo
//! feature (the `xla` crate is not in the offline vendor set), and at
//! run time it requires `make artifacts` (skips with a notice when
//! absent, so `cargo test --features pjrt` stays green in a fresh
//! checkout).
#![cfg(feature = "pjrt")]

use convbench::analytic::Primitive;
use convbench::coordinator::{artifact_inputs, kernel_layer, validate_primitive};
use convbench::models::{experiment_input, experiment_layer};
use convbench::nn::NoopMonitor;
use convbench::runtime::{artifact_path, list_artifacts, Runtime};

fn artifacts_dir() -> Option<String> {
    let dir = "artifacts".to_string();
    if std::path::Path::new(&artifact_path(&dir, "kernel_standard")).exists() {
        Some(dir)
    } else {
        eprintln!("skipping runtime integration tests: run `make artifacts` first");
        None
    }
}

#[test]
fn all_kernel_artifacts_bit_exact() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().expect("pjrt cpu client");
    for prim in Primitive::ALL {
        let v = validate_primitive(&rt, &dir, prim).expect("validation ran");
        assert!(
            v.passed(),
            "{}: {}/{} mismatches, first {:?}",
            v.artifact,
            v.mismatches,
            v.elements,
            v.first_mismatch
        );
    }
}

#[test]
fn artifact_listing_contains_all_kernels() {
    let Some(dir) = artifacts_dir() else { return };
    let names = list_artifacts(&dir);
    for prim in Primitive::ALL {
        let want = format!("kernel_{}", prim.name());
        assert!(names.contains(&want), "missing {want} in {names:?}");
    }
}

#[test]
fn runtime_rejects_missing_artifact() {
    let Some(_) = artifacts_dir() else { return };
    let rt = Runtime::cpu().expect("pjrt cpu client");
    assert!(rt.load_hlo_text("artifacts/nonexistent.hlo.txt").is_err());
}

#[test]
fn artifact_is_input_sensitive() {
    // flipping one input value must change the artifact output — guards
    // against a constant-folded or weight-baked artifact
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().expect("pjrt cpu client");
    let p = kernel_layer();
    let model = experiment_layer(&p, Primitive::Standard, convbench::coordinator::validate::VALIDATE_SEED);
    let x = experiment_input(&p, convbench::coordinator::validate::VALIDATE_SEED);
    let loaded = rt
        .load_hlo_text(artifact_path(&dir, "kernel_standard"))
        .expect("load");
    let base = loaded.run_i32(&artifact_inputs(&model, &x)).expect("run");
    let mut x2 = x.clone();
    x2.data[0] = x2.data[0].wrapping_add(40);
    let flipped = loaded.run_i32(&artifact_inputs(&model, &x2)).expect("run");
    assert_ne!(base[0], flipped[0], "artifact ignored its input");
    // and the engine agrees with the perturbed run too
    let want: Vec<i32> = model
        .forward(&x2, true, &mut NoopMonitor)
        .data
        .iter()
        .map(|&v| v as i32)
        .collect();
    assert_eq!(flipped[0], want);
}
