//! Integration across the rust stack: analytic ↔ engine ↔ simulator ↔
//! harness consistency on realistic layer sizes, plus the deployment
//! pipeline and server end to end.

use convbench::analytic::{costs, Primitive};
use convbench::harness::{measure_model, quick_plans, run_sweep};
use convbench::mcu::{McuConfig, OptLevel};
use convbench::models::{experiment_input, experiment_layer, mcunet, LayerParams};
use convbench::nn::{CountingMonitor, NoopMonitor};
use convbench::util::prng::Rng;

/// Table 1's closed forms must agree with the *counted* MAC work of the
/// engine (within border effects) — theory meets implementation.
#[test]
fn counted_macs_track_table1() {
    let p = LayerParams::new(2, 3, 16, 8, 8);
    let x = experiment_input(&p, 1);
    for prim in Primitive::ALL {
        let model = experiment_layer(&p, prim, 1);
        let mut mon = CountingMonitor::new();
        model.forward(&x, false, &mut mon);
        let theory = costs(&p, prim).macs;
        let counted = match prim {
            // add conv counts its taps as 2-alu groups
            Primitive::Add => mon.counts.alu / 2,
            _ => mon.counts.mac,
        };
        let ratio = counted as f64 / theory as f64;
        assert!(
            (0.7..=1.3).contains(&ratio),
            "{prim:?}: counted {counted} vs theory {theory} (ratio {ratio:.3})"
        );
    }
}

/// SIMD effective MACs (2 per SMLAD) must cover the same work.
#[test]
fn simd_effective_macs_cover_theory() {
    let p = LayerParams::new(2, 3, 16, 8, 8);
    let x = experiment_input(&p, 2);
    for prim in Primitive::ALL.iter().filter(|p| p.has_simd()) {
        let model = experiment_layer(&p, *prim, 2);
        let mut mon = CountingMonitor::new();
        model.forward(&x, true, &mut mon);
        let theory = costs(&p, *prim).macs;
        let eff = mon.counts.effective_macs();
        // im2col computes padded taps too (eff > theory), while the
        // depthwise stage clips border taps (eff slightly < theory)
        assert!(
            eff * 10 >= theory * 9 && eff <= theory * 3 / 2,
            "{prim:?}: effective {eff} vs theory {theory}"
        );
    }
}

/// Grouped convolution's measured latency must scale ~1/G (Table 1).
#[test]
fn grouped_latency_scales_inverse_g() {
    let cfg = McuConfig::default();
    let mut lat = Vec::new();
    for g in [1usize, 2, 4, 8] {
        let p = LayerParams::new(g, 3, 10, 16, 16);
        let model = experiment_layer(&p, Primitive::Grouped, 3);
        let x = experiment_input(&p, 3);
        lat.push(measure_model(&model, &x, false, &cfg).latency_s);
    }
    for i in 1..lat.len() {
        let gain = lat[i - 1] / lat[i];
        assert!(
            (1.6..=2.4).contains(&gain),
            "G doubling gave latency gain {gain:.2} at step {i}"
        );
    }
}

/// The κ order holds on every layer in the sweep (SIMD faster at Os,
/// slower to collapse at O0 than scalar).
#[test]
fn optlevel_ordering_holds_across_sweep() {
    let plan = &quick_plans()[3];
    for point in run_sweep(plan, &[Primitive::Standard], &McuConfig::default()) {
        let o0 = McuConfig {
            freq_mhz: 84.0,
            opt: OptLevel::O0,
        };
        let model = experiment_layer(&point.params, Primitive::Standard, 0xEC0 + plan.id as u64);
        let x = experiment_input(&point.params, 0x11A + point.axis_value as u64);
        let scalar_o0 = measure_model(&model, &x, false, &o0);
        let simd_o0 = measure_model(&model, &x, true, &o0);
        // at O0, SIMD barely helps (paper: ×1.17)
        let speedup_o0 = scalar_o0.latency_s / simd_o0.latency_s;
        assert!(
            (0.8..=2.0).contains(&speedup_o0),
            "O0 SIMD speedup {speedup_o0:.2} out of the paper's regime"
        );
        // and costs more energy per joule efficiency than at Os
        assert!(simd_o0.energy_mj > point.simd.unwrap().energy_mj);
    }
}

/// Deployment pipeline → engine → server, full loop on a small model.
#[test]
fn pipeline_to_server_loop() {
    use convbench::coordinator::{InferenceServer, Request};
    let models: Vec<_> = [Primitive::Standard, Primitive::DepthwiseSeparable]
        .iter()
        .map(|&p| mcunet(p, 9))
        .collect();
    let server = InferenceServer::start(models, 2, &McuConfig::default());
    let mut rng = Rng::new(4);
    for i in 0..12u64 {
        let mut input = vec![0i8; 32 * 32 * 3];
        rng.fill_i8(&mut input, -64, 63);
        let model = if i % 2 == 0 {
            "mcunet-standard"
        } else {
            "mcunet-dws"
        };
        let r = server
            .infer(Request::new(i, model, input))
            .expect("inference");
        assert_eq!(r.logits.len(), 10);
    }
    let stats = server.shutdown();
    assert_eq!(stats.served, 12);
    assert_eq!(stats.errors, 0);
}

/// Whole-model scalar/SIMD parity on every primitive at a non-trivial
/// input (integration-scale re-check of the per-layer property).
#[test]
fn model_level_parity_all_primitives() {
    let mut rng = Rng::new(8);
    for prim in Primitive::ALL {
        let m = mcunet(prim, 21);
        let mut x = convbench::nn::Tensor::zeros(m.input_shape, m.input_q);
        rng.fill_i8(&mut x.data, -96, 95);
        let a = m.forward(&x, false, &mut NoopMonitor);
        let b = m.forward(&x, true, &mut NoopMonitor);
        assert_eq!(a.data, b.data, "{prim:?}");
    }
}

/// Energy accounting is additive and consistent between the per-layer
/// and whole-model measurement paths.
#[test]
fn measurement_additivity() {
    let cfg = McuConfig::default();
    let p = LayerParams::new(2, 3, 12, 8, 8);
    let model = experiment_layer(&p, Primitive::DepthwiseSeparable, 5);
    let x = experiment_input(&p, 5);
    let whole = measure_model(&model, &x, true, &cfg);
    // manual per-layer accumulation
    let (_, profiles) = model.forward_profiled(&x, true);
    let sum_cycles: f64 = profiles
        .iter()
        .zip(&model.layers)
        .map(|(prof, layer)| {
            let path = if layer.has_simd() {
                convbench::mcu::PathClass::Simd
            } else {
                convbench::mcu::PathClass::Scalar
            };
            convbench::mcu::measure(&prof.counts, path, &cfg).cycles
        })
        .sum();
    assert!((whole.cycles - sum_cycles).abs() < 1e-6);
}
