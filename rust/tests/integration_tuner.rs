//! Integration: the schedule auto-tuner against the sweep harness — the
//! acceptance criteria of the tuner subsystem:
//!
//! 1. for every Table 2 workload, the tuned schedule's simulated latency
//!    (under the latency objective) and energy (under the energy
//!    objective) are ≤ the best fixed (path) configuration the sweep
//!    harness measures for that primitive;
//! 2. tuning is analytic: cold and warm runs alike perform **zero**
//!    instrumented simulator evaluations (cold runs score the space in
//!    closed form; warm runs replay the persisted cache without even
//!    that), and the cache file round-trips;
//! 3. tuned execution stays bit-exact with the engine.

use convbench::analytic::Primitive;
use convbench::harness::{measure_model, quick_plans, table2_plans, tuned_vs_fixed};
use convbench::mcu::McuConfig;
use convbench::models::{experiment_input, experiment_layer};
use convbench::nn::NoopMonitor;
use convbench::tuner::{tune_model, Objective, TuningCache};

#[test]
fn tuned_beats_or_ties_best_fixed_on_every_table2_workload() {
    // quick-sized variants of the five Table 2 experiments (same axes);
    // the full-size bases go through the same code in `convbench tune`
    let cfg = McuConfig::default();
    let mut cache = TuningCache::in_memory();
    let rows = tuned_vs_fixed(&quick_plans(), &cfg, &mut cache);
    assert_eq!(rows.len(), 5 * Primitive::ALL.len());
    for r in &rows {
        let best_lat = r.best_fixed_latency_s();
        let best_en = r.best_fixed_energy_mj();
        assert!(
            r.tuned_latency.latency_s <= best_lat + 1e-12,
            "exp {} {:?}: tuned latency {} > best fixed {}",
            r.experiment,
            r.primitive,
            r.tuned_latency.latency_s,
            best_lat
        );
        assert!(
            r.tuned_energy.energy_mj <= best_en + 1e-12,
            "exp {} {:?}: tuned energy {} > best fixed {}",
            r.experiment,
            r.primitive,
            r.tuned_energy.energy_mj,
            best_en
        );
        assert!(r.tuned_is_never_worse(), "exp {} {:?}", r.experiment, r.primitive);
    }
}

#[test]
fn one_full_size_table2_base_tunes_no_worse_than_fixed() {
    // one full-size Table 2 base per CI run keeps the test budget sane
    // while pinning the claim at paper scale (exp 2: G=2, k=3, 32×32×16)
    let cfg = McuConfig::default();
    let plan = &table2_plans()[1];
    let model = experiment_layer(&plan.base, Primitive::Standard, 1);
    let x = experiment_input(&plan.base, 2);
    let mut cache = TuningCache::in_memory();
    let (sched, _) = tune_model(&model, &x, &cfg, Objective::Latency, &mut cache);
    let scalar = measure_model(&model, &x, false, &cfg);
    let simd = measure_model(&model, &x, true, &cfg);
    assert!(sched.latency_s <= scalar.latency_s.min(simd.latency_s) + 1e-12);
    // at Os the SIMD path must be the floor the tuner starts from
    assert!(sched.latency_s <= simd.latency_s + 1e-12);
}

#[test]
fn warm_cache_file_round_trip_performs_zero_evaluations() {
    let cfg = McuConfig::default();
    let dir = std::env::temp_dir().join("convbench-tuner-integration");
    let path = dir.join("cache.json");
    let path = path.to_str().unwrap().to_string();
    let _ = std::fs::remove_file(&path);

    let plans = quick_plans();
    {
        let mut cache = TuningCache::load(&path);
        let rows = tuned_vs_fixed(&plans[..2], &cfg, &mut cache);
        let cold_evals: usize = rows.iter().map(|r| r.stats.evaluations).sum();
        let cold_scored: usize = rows.iter().map(|r| r.stats.analytic).sum();
        assert_eq!(cold_evals, 0, "cold tune must be analytic (zero instrumented forwards)");
        assert!(cold_scored > 0, "cold tune must score the candidate space");
        cache.save().expect("persist tuning cache");
    }
    {
        // a fresh process would do exactly this: reload and replay
        let mut cache = TuningCache::load(&path);
        assert!(!cache.is_empty());
        let rows = tuned_vs_fixed(&plans[..2], &cfg, &mut cache);
        let warm_evals: usize = rows.iter().map(|r| r.stats.evaluations).sum();
        let warm_scored: usize = rows.iter().map(|r| r.stats.analytic).sum();
        let warm_hits: usize = rows.iter().map(|r| r.stats.cache_hits).sum();
        assert_eq!(warm_evals, 0, "warm cache must perform zero simulator evaluations");
        assert_eq!(warm_scored, 0, "warm cache must not re-run the shape arithmetic");
        assert!(warm_hits > 0);
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn tuned_schedules_stay_bit_exact_across_the_zoo() {
    use convbench::models::mcunet;
    use convbench::nn::Tensor;
    use convbench::util::prng::Rng;
    let cfg = McuConfig::default();
    let mut cache = TuningCache::in_memory();
    let mut rng = Rng::new(77);
    for prim in Primitive::ALL {
        let model = mcunet(prim, 13);
        let mut x = Tensor::zeros(model.input_shape, model.input_q);
        rng.fill_i8(&mut x.data, -96, 95);
        for objective in [Objective::Latency, Objective::Energy, Objective::PeakRam] {
            let (sched, _) = tune_model(&model, &x, &cfg, objective, &mut cache);
            let want = model.forward(&x, true, &mut NoopMonitor);
            let got = sched.run(&model, &x, &mut NoopMonitor);
            assert_eq!(want.data, got.data, "{prim:?} under {:?}", objective);
        }
    }
}
